"""Symbolic model of ``pallas_call`` sites, extracted from the AST.

The PAL rule family (rules_pallas.py) and the pruning-readiness report
(kernel_report.py) both need the same facts about every Pallas kernel:
the grid, the in/out BlockSpecs with their index-map lambdas, the
kernel function(s) a call site can dispatch to, scratch shapes and
``dimension_semantics``. This module extracts them statically — no jax
import, pure ``ast`` — so the checks run in the dep-free
``static-analysis`` CI job before any test matrix spins up.

Resolution model (deliberately simple, matched to the repo's kernel
idiom — see DESIGN.md §14):

  * a block dim that is a constant resolves to itself;
  * a Name resolves through the entry function's local assignments
    (tuple-unpacking included), then its parameter default, then the
    ``nominal`` table (``roofline.hlo_costs.PALLAS_NOMINAL_DIMS``) —
    ``bm = min(block_m, M)`` with unknown runtime ``M`` resolves to the
    declared default of ``block_m``, i.e. the per-step tile ceiling;
  * ``min``/``max`` over partially-resolvable args take the resolvable
    subset; arithmetic (`+ - * //`) folds when both sides resolve;
  * everything else stays symbolic (reported by name, priced as
    unresolved).

Index maps are classified per output element and the worst class wins:

  * ``affine``      — constants, grid indices, and +/-/× by
    grid-constant terms (prunable by scalar-prefetch index rewriting);
  * ``affine_div``  — a grid index under integer division by a
    grid-constant (the GQA ``h // G`` map; prunable with a gather);
  * ``non_affine``  — anything else (data-dependent or multiplicative
    in two grid indices; not statically prunable).
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Set, Tuple

from repro.analysis.core import SourceModule, resolve_call_name

AFFINE = "affine"
AFFINE_DIV = "affine_div"
NON_AFFINE = "non_affine"

_CLASS_RANK = {AFFINE: 0, AFFINE_DIV: 1, NON_AFFINE: 2}

PALLAS_CALL = "jax.experimental.pallas.pallas_call"
BLOCK_SPEC = "jax.experimental.pallas.BlockSpec"
PARTIAL = "functools.partial"

#: Per-operand price of the traffic model: the model is *relative* (a
#: drift detector for BlockSpec edits), so every operand is priced at
#: f32 regardless of runtime dtype.
MODEL_DTYPE_BYTES = 4.0


@dataclasses.dataclass(frozen=True)
class IndexMapModel:
    """One BlockSpec index-map lambda."""
    params: Tuple[str, ...]
    exprs: Tuple[str, ...]        # unparsed output elements
    classes: Tuple[str, ...]      # per-element classification
    lineno: int

    @property
    def classification(self) -> str:
        worst = AFFINE
        for c in self.classes:
            if _CLASS_RANK[c] > _CLASS_RANK[worst]:
                worst = c
        return worst


@dataclasses.dataclass(frozen=True)
class SpecModel:
    """One BlockSpec operand of a pallas_call."""
    role: str                               # "in" | "out"
    position: int                           # index within the role
    block_shape: Optional[Tuple[str, ...]]  # unparsed dims (None: no shape)
    resolved: Optional[Tuple[Optional[int], ...]]
    index_map: Optional[IndexMapModel]
    memory_space: Optional[str]             # "SMEM" | "ANY" | ... | None
    conditional: bool                       # appended in a branch
    lineno: int

    @property
    def block_elems(self) -> Optional[int]:
        if self.resolved is None or any(d is None for d in self.resolved):
            return None
        n = 1
        for d in self.resolved:
            n *= d
        return n

    @property
    def unresolved_dims(self) -> Tuple[str, ...]:
        if self.block_shape is None or self.resolved is None:
            return ()
        return tuple(s for s, r in zip(self.block_shape, self.resolved)
                     if r is None)


@dataclasses.dataclass(frozen=True)
class PallasCallModel:
    """One pallas_call site inside a top-level entry function."""
    relpath: str
    entry: str                    # enclosing top-level function
    entry_lineno: int
    lineno: int                   # the call site
    grid_rank: Optional[int]      # None: not statically resolvable
    grid_exprs: Tuple[str, ...]
    kernel_names: Tuple[str, ...]   # candidate kernel functions
    in_specs: Tuple[SpecModel, ...]
    out_specs: Tuple[SpecModel, ...]
    n_scratch: int
    scratch_exprs: Tuple[str, ...]
    dimension_semantics: Optional[Tuple[str, ...]]

    @property
    def key(self) -> str:
        """Budget-table key (roofline.hlo_costs.PALLAS_TILE_BUDGETS)."""
        return f"{self.relpath}::{self.entry}"

    @property
    def specs(self) -> Tuple[SpecModel, ...]:
        return self.in_specs + self.out_specs

    def bytes_per_step(self) -> Tuple[Optional[float], Tuple[str, ...]]:
        """(HBM bytes moved per grid step under the f32 model,
        unresolved dim names). SMEM/shapeless operands are free —
        scalar predicates and full-operand ANY specs are not part of
        the per-step streaming traffic."""
        total = 0.0
        unresolved: List[str] = []
        for spec in self.specs:
            if spec.block_shape is None or spec.memory_space == "SMEM":
                continue
            elems = spec.block_elems
            if elems is None:
                unresolved.extend(spec.unresolved_dims)
                continue
            total += elems * MODEL_DTYPE_BYTES
        if unresolved:
            return None, tuple(dict.fromkeys(unresolved))
        return total, ()


# --------------------------------------------------------------------------
# entry-function environment
# --------------------------------------------------------------------------

class _Env:
    """Local assignments, list-appends and parameter defaults of one
    entry function, for constant folding and name resolution."""

    def __init__(self, fn: ast.FunctionDef):
        self.assigns: Dict[str, List[ast.expr]] = {}
        self.appends: Dict[str, List[ast.expr]] = {}
        self.defaults: Dict[str, ast.expr] = {}
        args = fn.args
        pos = args.posonlyargs + args.args
        for a, d in zip(pos[len(pos) - len(args.defaults):], args.defaults):
            self.defaults[a.arg] = d
        for a, d in zip(args.kwonlyargs, args.kw_defaults):
            if d is not None:
                self.defaults[a.arg] = d
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    self._record(t, node.value)
            elif isinstance(node, ast.AugAssign) and isinstance(
                    node.target, ast.Name):
                # x = <unfoldable>: kill constant resolution for x
                self.assigns.setdefault(node.target.id, []).append(node)
            elif (isinstance(node, ast.Expr)
                  and isinstance(node.value, ast.Call)
                  and isinstance(node.value.func, ast.Attribute)
                  and node.value.func.attr == "append"
                  and isinstance(node.value.func.value, ast.Name)
                  and node.value.args):
                self.appends.setdefault(
                    node.value.func.value.id, []).append(node.value.args[0])

    def _record(self, target: ast.expr, value: ast.expr) -> None:
        if isinstance(target, ast.Name):
            self.assigns.setdefault(target.id, []).append(value)
        elif isinstance(target, (ast.Tuple, ast.List)):
            if (isinstance(value, (ast.Tuple, ast.List))
                    and len(value.elts) == len(target.elts)):
                for t, v in zip(target.elts, value.elts):
                    self._record(t, v)
            else:   # unpacking an opaque value: record as unresolvable
                for t in target.elts:
                    if isinstance(t, ast.Name):
                        self.assigns.setdefault(t.id, []).append(value)

    def lookup(self, name: str) -> List[ast.expr]:
        return self.assigns.get(name, [])


def _resolve_int(node: ast.AST, env: _Env, nominal: Mapping[str, int],
                 visiting: Optional[Set[str]] = None) -> Optional[int]:
    visiting = visiting if visiting is not None else set()
    if isinstance(node, ast.Constant):
        return node.value if isinstance(node.value, int) else None
    if isinstance(node, ast.Name):
        if node.id not in visiting:
            # only the LAST assignment counts: an earlier `rows = 1`
            # must not leak through a later unresolvable `rows *= s`
            values = env.lookup(node.id)
            if values:
                r = _resolve_int(values[-1], env, nominal,
                                 visiting | {node.id})
                if r is not None:
                    return r
        d = env.defaults.get(node.id)
        if d is not None:
            r = _resolve_int(d, env, nominal, visiting | {node.id})
            if r is not None:
                return r
        return nominal.get(node.id)
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        r = _resolve_int(node.operand, env, nominal, visiting)
        return -r if r is not None else None
    if isinstance(node, ast.BinOp):
        lh = _resolve_int(node.left, env, nominal, visiting)
        rh = _resolve_int(node.right, env, nominal, visiting)
        if lh is None or rh is None:
            return None
        if isinstance(node.op, ast.Add):
            return lh + rh
        if isinstance(node.op, ast.Sub):
            return lh - rh
        if isinstance(node.op, ast.Mult):
            return lh * rh
        if isinstance(node.op, ast.FloorDiv) and rh != 0:
            return lh // rh
        if isinstance(node.op, ast.Mod) and rh != 0:
            return lh % rh
        return None
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id in ("min", "max"):
        vals = [_resolve_int(a, env, nominal, visiting) for a in node.args]
        vals = [v for v in vals if v is not None]
        if not vals:
            return None
        return min(vals) if node.func.id == "min" else max(vals)
    return None


# --------------------------------------------------------------------------
# index-map classification
# --------------------------------------------------------------------------

def _contains_param(node: ast.AST, params: Set[str]) -> bool:
    return any(isinstance(n, ast.Name) and n.id in params
               for n in ast.walk(node))


def classify_index_expr(node: ast.AST, params: Set[str]) -> str:
    """Classify one index-map output element (see module docstring)."""
    if isinstance(node, ast.Constant):
        return AFFINE if isinstance(node.value, int) else NON_AFFINE
    if isinstance(node, ast.Name):
        return AFFINE     # grid index or closure constant, both affine
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        return classify_index_expr(node.operand, params)
    if isinstance(node, ast.BinOp):
        lc = classify_index_expr(node.left, params)
        rc = classify_index_expr(node.right, params)
        worst = max(lc, rc, key=lambda c: _CLASS_RANK[c])
        if isinstance(node.op, (ast.Add, ast.Sub)):
            return worst
        if isinstance(node.op, ast.Mult):
            if (_contains_param(node.left, params)
                    and _contains_param(node.right, params)):
                return NON_AFFINE   # quadratic in grid indices
            return worst
        if isinstance(node.op, (ast.FloorDiv, ast.Mod)):
            if _contains_param(node.right, params):
                return NON_AFFINE   # grid index in the divisor
            if not _contains_param(node.left, params):
                return worst        # pure constant expression
            if lc == NON_AFFINE:
                return NON_AFFINE
            return AFFINE_DIV       # the h // G pattern
        return NON_AFFINE
    return NON_AFFINE


def _model_index_map(node: ast.AST) -> Optional[IndexMapModel]:
    if not isinstance(node, ast.Lambda):
        return None
    params = tuple(a.arg for a in node.args.posonlyargs + node.args.args)
    body = node.body
    elts = list(body.elts) if isinstance(body, (ast.Tuple, ast.List)) \
        else [body]
    pset = set(params)
    return IndexMapModel(
        params=params,
        exprs=tuple(ast.unparse(e) for e in elts),
        classes=tuple(classify_index_expr(e, pset) for e in elts),
        lineno=node.lineno)


# --------------------------------------------------------------------------
# BlockSpec / pallas_call extraction
# --------------------------------------------------------------------------

def _is_call_to(mod: SourceModule, node: ast.AST, canonical: str) -> bool:
    return (isinstance(node, ast.Call)
            and resolve_call_name(mod, node.func) == canonical)


def _model_spec(mod: SourceModule, call: ast.Call, role: str, position: int,
                env: _Env, nominal: Mapping[str, int],
                conditional: bool) -> SpecModel:
    block_shape = resolved = None
    index_map = None
    memory_space = None
    args = list(call.args)
    if args and isinstance(args[0], (ast.Tuple, ast.List)):
        dims = args[0].elts
        block_shape = tuple(ast.unparse(d) for d in dims)
        resolved = tuple(_resolve_int(d, env, nominal) for d in dims)
    if len(args) > 1:
        index_map = _model_index_map(args[1])
    for kw in call.keywords:
        if kw.arg == "index_map":
            index_map = _model_index_map(kw.value)
        elif kw.arg == "block_shape" and isinstance(
                kw.value, (ast.Tuple, ast.List)):
            dims = kw.value.elts
            block_shape = tuple(ast.unparse(d) for d in dims)
            resolved = tuple(_resolve_int(d, env, nominal) for d in dims)
        elif kw.arg == "memory_space":
            dotted = ast.unparse(kw.value)
            memory_space = dotted.rsplit(".", 1)[-1]
    return SpecModel(role=role, position=position, block_shape=block_shape,
                     resolved=resolved, index_map=index_map,
                     memory_space=memory_space, conditional=conditional,
                     lineno=call.lineno)


def _spec_nodes(mod: SourceModule, node: ast.AST, env: _Env
                ) -> List[Tuple[ast.Call, bool]]:
    """Resolve an in_specs/out_specs expression to BlockSpec call nodes,
    following one level of local-name indirection plus ``.append`` calls
    (the masked-operand idiom: build the base list, append the SMEM
    predicate spec under ``if active is not None``)."""
    out: List[Tuple[ast.Call, bool]] = []

    def collect(n: ast.AST, conditional: bool):
        if isinstance(n, (ast.List, ast.Tuple)):
            for el in n.elts:
                collect(el, conditional)
        elif _is_call_to(mod, n, BLOCK_SPEC):
            out.append((n, conditional))

    if isinstance(node, ast.Name):
        values = env.lookup(node.id)
        if values:
            collect(values[-1], False)
        for appended in env.appends.get(node.id, []):
            collect(appended, True)
    else:
        collect(node, False)
    return out


def _kernel_candidates(mod: SourceModule, node: ast.AST, env: _Env,
                       toplevel: Set[str],
                       visiting: Optional[Set[str]] = None) -> Set[str]:
    visiting = visiting or set()
    if isinstance(node, ast.Name):
        if node.id in toplevel:
            return {node.id}
        if node.id in visiting:
            return set()
        names: Set[str] = set()
        for value in env.lookup(node.id):
            names |= _kernel_candidates(mod, value, env, toplevel,
                                        visiting | {node.id})
        return names
    if isinstance(node, ast.Call) and resolve_call_name(
            mod, node.func) == PARTIAL and node.args:
        return _kernel_candidates(mod, node.args[0], env, toplevel, visiting)
    return set()


def _dimension_semantics(node: ast.AST) -> Optional[Tuple[str, ...]]:
    for n in ast.walk(node):
        if isinstance(n, ast.keyword) and n.arg == "dimension_semantics":
            if isinstance(n.value, (ast.Tuple, ast.List)):
                vals = []
                for el in n.value.elts:
                    if (isinstance(el, ast.Constant)
                            and isinstance(el.value, str)):
                        vals.append(el.value)
                    else:
                        return None
                return tuple(vals)
    return None


def _model_call(mod: SourceModule, fn: ast.FunctionDef, call: ast.Call,
                env: _Env, nominal: Mapping[str, int],
                toplevel: Set[str]) -> PallasCallModel:
    grid_rank = None
    grid_exprs: Tuple[str, ...] = ()
    in_specs: List[SpecModel] = []
    out_specs: List[SpecModel] = []
    n_scratch = 0
    scratch_exprs: Tuple[str, ...] = ()
    dim_sem = None

    kernel_names = tuple(sorted(_kernel_candidates(
        mod, call.args[0], env, toplevel))) if call.args else ()

    for kw in call.keywords:
        if kw.arg == "grid":
            gnode = kw.value
            if isinstance(gnode, ast.Name):
                values = [v for v in env.lookup(gnode.id)
                          if isinstance(v, (ast.Tuple, ast.List))]
                gnode = values[-1] if values else gnode
            if isinstance(gnode, (ast.Tuple, ast.List)):
                grid_rank = len(gnode.elts)
                grid_exprs = tuple(ast.unparse(e) for e in gnode.elts)
            elif isinstance(gnode, ast.Constant) and isinstance(
                    gnode.value, int):
                grid_rank = 1
                grid_exprs = (repr(gnode.value),)
        elif kw.arg == "in_specs":
            for i, (spec, cond) in enumerate(
                    _spec_nodes(mod, kw.value, env)):
                in_specs.append(_model_spec(mod, spec, "in", i, env,
                                            nominal, cond))
        elif kw.arg == "out_specs":
            for i, (spec, cond) in enumerate(
                    _spec_nodes(mod, kw.value, env)):
                out_specs.append(_model_spec(mod, spec, "out", i, env,
                                             nominal, cond))
        elif kw.arg == "scratch_shapes":
            snode = kw.value
            if isinstance(snode, ast.Name):
                values = [v for v in env.lookup(snode.id)
                          if isinstance(v, (ast.Tuple, ast.List))]
                snode = values[-1] if values else snode
            if isinstance(snode, (ast.Tuple, ast.List)):
                n_scratch = len(snode.elts)
                scratch_exprs = tuple(ast.unparse(e) for e in snode.elts)
        elif kw.arg == "compiler_params":
            dim_sem = _dimension_semantics(kw.value)

    return PallasCallModel(
        relpath=mod.relpath, entry=fn.name, entry_lineno=fn.lineno,
        lineno=call.lineno, grid_rank=grid_rank, grid_exprs=grid_exprs,
        kernel_names=kernel_names, in_specs=tuple(in_specs),
        out_specs=tuple(out_specs), n_scratch=n_scratch,
        scratch_exprs=scratch_exprs, dimension_semantics=dim_sem)


def extract_pallas_calls(mod: SourceModule, nominal: Mapping[str, int]
                         ) -> List[PallasCallModel]:
    """All pallas_call sites in a module, one model per site, in source
    order. Only call sites inside top-level functions are modeled (the
    repo idiom: one entry function per kernel)."""
    cached = getattr(mod, "_pallas_models", None)
    if cached is not None:
        return cached
    toplevel = {n.name for n in mod.tree.body
                if isinstance(n, ast.FunctionDef)}
    models: List[PallasCallModel] = []
    for fn in mod.tree.body:
        if not isinstance(fn, ast.FunctionDef):
            continue
        env = _Env(fn)
        for node in ast.walk(fn):
            if _is_call_to(mod, node, PALLAS_CALL):
                models.append(_model_call(mod, fn, node, env, nominal,
                                          toplevel))
    models.sort(key=lambda m: m.lineno)
    mod._pallas_models = models
    return models


def find_kernel_def(mod: SourceModule, name: str
                    ) -> Optional[ast.FunctionDef]:
    for node in mod.tree.body:
        if isinstance(node, ast.FunctionDef) and node.name == name:
            return node
    return None


# --------------------------------------------------------------------------
# kernel-body analysis (guards, accumulation, lane gating)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class GuardModel:
    """One ``@pl.when(cond)``-decorated inner def of a kernel."""
    node: ast.FunctionDef
    kind: str                     # "zero" | "last" | "other"
    axes: Tuple[int, ...]         # program_id axes named in the condition
    lane_gated: bool              # condition derives from a lane predicate


@dataclasses.dataclass
class KernelBodyModel:
    """Static facts about one kernel function's body (PAL403-405)."""
    name: str
    node: ast.FunctionDef
    params: Tuple[str, ...]       # positional parameter names
    program_axes: Dict[str, int]  # local name -> pl.program_id axis
    guards: List[GuardModel]
    accumulated: Set[str]         # scratch params updated from themselves
    dots: List[ast.Call]          # dot_general / einsum / dot call sites
    lane_gated: bool              # some guard gates on a lane predicate

    def gated_nodes(self) -> Set[int]:
        ids: Set[int] = set()
        for g in self.guards:
            if g.lane_gated:
                for n in ast.walk(g.node):
                    ids.add(id(n))
        return ids


_DOT_TAILS = ("dot_general", "einsum", "dot")


def _stmt_iter(fn: ast.FunctionDef):
    """Statements of a function in source order, descending into
    compound statements but not nested defs."""
    def walk(stmts):
        for s in stmts:
            yield s
            if isinstance(s, (ast.If, ast.For, ast.While, ast.With)):
                for attr in ("body", "orelse", "finalbody"):
                    yield from walk(getattr(s, attr, []) or [])
    yield from walk(fn.body)


def _subscript_reads(node: ast.AST, names: Set[str]) -> Set[str]:
    """Names from ``names`` read via subscript anywhere under node."""
    out: Set[str] = set()
    for n in ast.walk(node):
        if (isinstance(n, ast.Subscript) and isinstance(n.value, ast.Name)
                and n.value.id in names):
            out.add(n.value.id)
    return out


def _names_in(node: ast.AST) -> Set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def _is_lane_pred(node: ast.AST, params: Set[str],
                  program_axes: Mapping[str, int]) -> bool:
    """``param_ref[program_id_local] ==/!= const`` — the SMEM lane
    predicate read that PAL403 requires the compute to be gated on."""
    if not (isinstance(node, ast.Compare) and len(node.ops) == 1
            and isinstance(node.ops[0], (ast.Eq, ast.NotEq))):
        return False
    for side in (node.left, node.comparators[0]):
        if (isinstance(side, ast.Subscript)
                and isinstance(side.value, ast.Name)
                and side.value.id in params
                and isinstance(side.slice, ast.Name)
                and side.slice.id in program_axes):
            return True
    return False


def _guard_kind(cond: ast.AST, program_axes: Mapping[str, int]
                ) -> Tuple[str, Tuple[int, ...]]:
    """Classify a pl.when condition: the ``k == 0`` init form, the
    ``k == nk - 1`` final-write form, or other. Axes are the
    program_id axes of any locals named in the condition."""
    axes = tuple(sorted({program_axes[n] for n in _names_in(cond)
                         if n in program_axes}))
    if isinstance(cond, ast.Compare) and len(cond.ops) == 1 \
            and isinstance(cond.ops[0], ast.Eq):
        sides = (cond.left, cond.comparators[0])
        for a, b in (sides, sides[::-1]):
            if not (isinstance(a, ast.Name) and a.id in program_axes):
                continue
            if isinstance(b, ast.Constant) and b.value == 0:
                return "zero", axes
            if (isinstance(b, ast.BinOp) and isinstance(b.op, ast.Sub)
                    and isinstance(b.right, ast.Constant)
                    and b.right.value == 1):
                return "last", axes
    return "other", axes


def analyze_kernel(mod: SourceModule, name: str,
                   n_out: int, n_scratch: int
                   ) -> Optional[KernelBodyModel]:
    """Static facts about a kernel function (cached per module+name).

    Parameter roles follow the pallas calling convention — positional
    params are ``(*inputs, *outputs, *scratch)`` — so the LAST
    ``n_scratch`` params are scratch refs and the ``n_out`` before them
    are output refs, independent of how many masked operands a call
    site conditionally appends."""
    cache = getattr(mod, "_kernel_bodies", None)
    if cache is None:
        cache = mod._kernel_bodies = {}
    ck = (name, n_out, n_scratch)
    if ck in cache:
        return cache[ck]

    fn = find_kernel_def(mod, name)
    if fn is None:
        cache[ck] = None
        return None
    params = tuple(a.arg for a in fn.args.posonlyargs + fn.args.args)
    pset = set(params)
    scratch = set(params[len(params) - n_scratch:]) if n_scratch else set()

    # pl.program_id / pl.num_programs locals
    program_axes: Dict[str, int] = {}
    for stmt in _stmt_iter(fn):
        if isinstance(stmt, ast.Assign) and isinstance(stmt.value, ast.Call):
            callee = resolve_call_name(mod, stmt.value.func) or ""
            if callee.endswith((".program_id", ".num_programs")) \
                    and stmt.value.args \
                    and isinstance(stmt.value.args[0], ast.Constant):
                for t in stmt.targets:
                    if isinstance(t, ast.Name):
                        program_axes[t.id] = stmt.value.args[0].value

    # lane-predicate taint: locals derived from a predicate read
    tainted: Set[str] = set()
    # scratch-read taint: locals derived from a scratch read
    scratch_taint: Dict[str, Set[str]] = {}
    for stmt in _stmt_iter(fn):
        if not isinstance(stmt, ast.Assign):
            continue
        value = stmt.value
        is_pred = _is_lane_pred(value, pset, program_axes) or bool(
            _names_in(value) & tainted)
        reads = _subscript_reads(value, scratch)
        for n in _names_in(value):
            reads |= scratch_taint.get(n, set())
        for t in stmt.targets:
            if isinstance(t, ast.Name):
                if is_pred:
                    tainted.add(t.id)
                if reads:
                    scratch_taint[t.id] = (
                        scratch_taint.get(t.id, set()) | reads)

    # accumulated scratch: written from its own value (directly or via a
    # tainted local), or augmented-assigned
    accumulated: Set[str] = set()
    for node in ast.walk(fn):
        target = value = None
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            target, value = node.targets[0], node.value
        elif isinstance(node, ast.AugAssign):
            target, value = node.target, node.value
        if not (isinstance(target, ast.Subscript)
                and isinstance(target.value, ast.Name)
                and target.value.id in scratch):
            continue
        s = target.value.id
        if isinstance(node, ast.AugAssign):
            accumulated.add(s)
            continue
        reads = _subscript_reads(value, scratch)
        for n in _names_in(value):
            reads |= scratch_taint.get(n, set())
        if s in reads:
            accumulated.add(s)

    # pl.when guards (decorator form)
    guards: List[GuardModel] = []
    for node in ast.walk(fn):
        if not isinstance(node, ast.FunctionDef) or node is fn:
            continue
        for deco in node.decorator_list:
            if not (isinstance(deco, ast.Call)
                    and (resolve_call_name(mod, deco.func) or ""
                         ).endswith(".when")
                    and deco.args):
                continue
            cond = deco.args[0]
            kind, axes = _guard_kind(cond, program_axes)
            lane = _is_lane_pred(cond, pset, program_axes) or bool(
                _names_in(cond) & tainted)
            guards.append(GuardModel(node=node, kind=kind, axes=axes,
                                     lane_gated=lane))

    dots = [n for n in ast.walk(fn)
            if isinstance(n, ast.Call)
            and (resolve_call_name(mod, n.func) or "").rsplit(".", 1)[-1]
            in _DOT_TAILS]

    body = KernelBodyModel(
        name=name, node=fn, params=params, program_axes=program_axes,
        guards=guards, accumulated=accumulated, dots=dots,
        lane_gated=any(g.lane_gated for g in guards))
    cache[ck] = body
    return body


def kernel_is_lane_gated(mod: SourceModule, body: KernelBodyModel) -> bool:
    """PAL403 pass criterion for one kernel function: a lane-predicate
    ``pl.when`` exists, every dot/einsum issues inside one, and for
    dot-free (VPU) kernels the gated region does the ref writes."""
    if not body.lane_gated:
        return False
    gated = body.gated_nodes()
    if body.dots:
        return all(id(d) in gated for d in body.dots)
    for g in body.guards:
        if not g.lane_gated:
            continue
        for n in ast.walk(g.node):
            if isinstance(n, (ast.Assign, ast.AugAssign)):
                targets = n.targets if isinstance(n, ast.Assign) \
                    else [n.target]
                if any(isinstance(t, ast.Subscript) for t in targets):
                    return True
    return False

"""Lint configuration: which files are decision-path, which callables
donate, which kernels owe the lane-mask contract.

This is the one place a contributor registers new surface area:

  * a new module whose outputs feed scheduling decisions goes into
    ``decision_modules`` (the DET family then bans wall-clock reads,
    unseeded RNG, set-order dependence, id() ordering and float ``==``
    gates in it);
  * a new donating step factory goes into ``donating_factories``;
  * a new packed/lane-batched kernel entrypoint goes into
    ``mask_entrypoints`` (MASK then enforces ``active=None`` + the
    passthrough branch);
  * a new paired monitor counter goes into ``acc_pairs``.

See DESIGN.md §13 and docs/SHARING_MODES.md ("adding a decision-path
module").
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, Mapping, Sequence, Tuple

#: Modules whose control flow decides *what runs where, when* — replays
#: are only bit-identical while these stay pure functions of recorded
#: inputs (DESIGN.md §6 invariants, §11 quality gate).
DECISION_MODULES = (
    "src/repro/core/simulate.py",
    "src/repro/core/tenancy.py",
    "src/repro/core/traces.py",
    "src/repro/core/spatial.py",
    "src/repro/core/triples.py",
    "src/repro/core/scheduler.py",
    "src/repro/core/monitor.py",
    "src/repro/core/eventlog.py",
    "src/repro/core/controlplane.py",
)

#: core/packing.py factories whose returned callable donates argument
#: positions (params, opt_state) — reading a local after passing it to
#: one of these is a use-after-free on device buffers (§7).
DONATING_FACTORIES: Mapping[str, Tuple[int, ...]] = {
    "packed_step": (0, 1),
    "packed_masked_step": (0, 1),
    "packed_compact_step": (0, 1),
    "packed_kernel_step": (0, 1),
    "masked_pool_step": (0, 1),
}

#: Packed / lane-batched kernel entrypoints owing the lane-mask
#: contract: accept ``active=`` defaulting to None, with an explicit
#: None passthrough (PR 7 contract; DESIGN.md §12).
MASK_ENTRYPOINTS: Mapping[str, Tuple[str, ...]] = {
    "src/repro/kernels/ops.py": (
        "packed_matmul", "packed_norm", "flash_attention", "ssd"),
    "src/repro/kernels/packed_gemm.py": ("packed_gemm",),
    "src/repro/kernels/fused_rmsnorm.py": ("packed_rmsnorm",),
    "src/repro/kernels/flash_attention.py": ("flash_attention_fwd",),
}

#: The masked-execution dispatcher must branch on every registered mode
#: (a mode in MASKED_MODES with no dispatcher arm is dead config).
MASK_DISPATCH = {
    "module": "src/repro/core/packing.py",
    "modes_const": "MASKED_MODES",
    "dispatcher": "masked_pool_step",
    "param": "mode",
}

#: Monitor counters that must be incremented in matched pairs at the
#: call-site layer — an unpaired member means gauges drift monotonic
#: and the LLload table lies (DESIGN.md §4).
ACC_PAIRS = (
    ("on_dispatch", "on_release"),
    ("on_preempt", "on_resume"),
    ("on_slice_alloc", "on_slice_release"),
)

#: Modules whose call sites the ACC family audits.
ACC_MODULES = (
    "src/repro/core/scheduler.py",
    "src/repro/core/simulate.py",
)

#: pallas_call-backed kernel entry functions that owe the *native* lane
#: mask: the kernel itself must gate its compute behind ``pl.when`` on
#: an SMEM lane predicate (PAL403). This is one level below
#: MASK_ENTRYPOINTS — an entrypoint can satisfy MASK201 with a where-
#: zero fallback, but a kernel registered here must not.
MASKED_KERNELS: Mapping[str, Tuple[str, ...]] = {
    "src/repro/kernels/packed_gemm.py": ("packed_gemm",),
    "src/repro/kernels/fused_rmsnorm.py": ("packed_rmsnorm",),
    "src/repro/kernels/flash_attention.py": ("flash_attention_fwd",),
    "src/repro/kernels/ssd_scan.py": ("ssd_scan",),
}


@dataclasses.dataclass(frozen=True)
class LintConfig:
    root: str
    paths: Tuple[str, ...] = ("src/repro",)
    decision_modules: Tuple[str, ...] = DECISION_MODULES
    donating_factories: Mapping[str, Tuple[int, ...]] = dataclasses.field(
        default_factory=lambda: dict(DONATING_FACTORIES))
    mask_entrypoints: Mapping[str, Tuple[str, ...]] = dataclasses.field(
        default_factory=lambda: dict(MASK_ENTRYPOINTS))
    mask_dispatch: Dict = dataclasses.field(
        default_factory=lambda: dict(MASK_DISPATCH))
    acc_pairs: Tuple[Tuple[str, str], ...] = ACC_PAIRS
    acc_modules: Tuple[str, ...] = ACC_MODULES
    masked_kernels: Mapping[str, Tuple[str, ...]] = dataclasses.field(
        default_factory=lambda: dict(MASKED_KERNELS))
    tile_budgets: Mapping[str, float] = dataclasses.field(
        default_factory=lambda: dict(_tile_budgets()))
    tile_nominal_dims: Mapping[str, int] = dataclasses.field(
        default_factory=lambda: dict(_nominal_dims()))
    tile_tolerance: float = 0.25
    baseline_path: str = "LINT_BASELINE.json"

    def is_decision(self, relpath: str) -> bool:
        return relpath in self.decision_modules

    def abs_baseline(self) -> str:
        if os.path.isabs(self.baseline_path):
            return self.baseline_path
        return os.path.join(self.root, self.baseline_path)


def _tile_budgets() -> Mapping[str, float]:
    """PAL406 budgets live next to the measured roofline numbers so a
    kernel change updates both in one review (hlo_costs is stdlib-only,
    so the lint stays dep-free)."""
    from repro.roofline.hlo_costs import PALLAS_TILE_BUDGETS
    return PALLAS_TILE_BUDGETS


def _nominal_dims() -> Mapping[str, int]:
    from repro.roofline.hlo_costs import PALLAS_NOMINAL_DIMS
    return PALLAS_NOMINAL_DIMS


def repo_root() -> str:
    """The checkout root, derived from this file's location
    (src/repro/analysis/config.py -> three levels up)."""
    here = os.path.abspath(os.path.dirname(__file__))
    return os.path.abspath(os.path.join(here, "..", "..", ".."))


def default_config(root: str | None = None, **overrides) -> LintConfig:
    return LintConfig(root=root or repo_root(), **overrides)

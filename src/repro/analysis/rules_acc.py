"""ACC — monitor-counter symmetry at call sites.

TenantGauges counters come in matched pairs: what on_dispatch adds,
on_release subtracts; every on_preempt expects an eventual on_resume;
every on_slice_alloc an on_slice_release. A call-site layer (the
scheduler's dispatch loop, the simulator) that invokes one member of a
pair and never the other leaks holdings monotonically — the LLload
table then lies to the operator and to the RepackController that feeds
on it (DESIGN.md §4, §9).

  ACC301  a module configured in ``acc_modules`` calls one member of an
          ``acc_pairs`` pair but never its partner.
"""
from __future__ import annotations

import ast
from typing import Dict, List

from repro.analysis.core import Finding, context_of, register


@register("ACC301", "counter-symmetry",
          "monitor counter pairs must both be called where either is")
def check_counter_symmetry(modules, config) -> List[Finding]:
    out: List[Finding] = []
    members = {m for pair in config.acc_pairs for m in pair}
    for mod in modules:
        if mod.relpath not in config.acc_modules:
            continue
        # first call site per callback name (attribute calls only:
        # `<gauges>.on_dispatch(...)`)
        sites: Dict[str, ast.Call] = {}
        for node in ast.walk(mod.tree):
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in members):
                sites.setdefault(node.func.attr, node)
        for a, b in config.acc_pairs:
            for present, absent in ((a, b), (b, a)):
                if present in sites and absent not in sites:
                    node = sites[present]
                    out.append(mod.finding(
                        "ACC301", "counter-symmetry", node,
                        f"module calls .{present}() but never "
                        f".{absent}() — the pair's gauges drift "
                        f"monotonically; call the partner on the "
                        f"matching lifecycle edge (or pragma if this "
                        f"layer genuinely only sees one edge)",
                        context_of(mod, node)))
    return out

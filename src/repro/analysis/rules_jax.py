"""JAX — donation and retrace rules.

  JAX101  use-after-donate: a local passed at a donated position of a
          donating callable (jax.jit(..., donate_argnums=...) or a
          core/packing.py step factory) is a dead device buffer; reading
          it afterwards is a use-after-free that XLA may or may not
          catch depending on backend.
  JAX102  jax.jit (or a donating step factory) constructed inside a
          loop body retraces per iteration — this is exactly the
          compile-once invariant (DESIGN.md §7) the lane pool's trace
          counter asserts at run time, checked statically.
  JAX103  Python `if`/`while` on a traced parameter of a jitted
          function escapes the trace (ConcretizationTypeError at best,
          silently-baked-in constant at worst).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.core import (Finding, SourceModule, context_of,
                                 register, resolve_call_name)

_JIT_NAMES = {"jax.jit", "jax.pjit", "jax.experimental.pjit.pjit"}
_VMAP_NAMES = {"jax.vmap"}


def _literal_positions(node: ast.AST) -> Optional[Tuple[int, ...]]:
    """Evaluate a donate_argnums literal (int or tuple of ints)."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        vals = []
        for el in node.elts:
            if not (isinstance(el, ast.Constant)
                    and isinstance(el.value, int)):
                return None
            vals.append(el.value)
        return tuple(vals)
    if isinstance(node, ast.IfExp):
        # `(0, 1) if donate else ()` — union both arms, conservatively
        a = _literal_positions(node.body) or ()
        b = _literal_positions(node.orelse) or ()
        return tuple(sorted(set(a) | set(b)))
    return None


def _donating_call(mod: SourceModule, node: ast.Call, config
                   ) -> Optional[Tuple[int, ...]]:
    """If ``node`` constructs a donating callable, return its donated
    argument positions."""
    name = resolve_call_name(mod, node.func)
    if name is None:
        return None
    base = name.rsplit(".", 1)[-1]
    if name in _JIT_NAMES:
        for kw in node.keywords:
            if kw.arg == "donate_argnums":
                return _literal_positions(kw.value)
        return None   # jit without donation: not a donating callable
    if base in config.donating_factories:
        for kw in node.keywords:
            if (kw.arg == "donate" and isinstance(kw.value, ast.Constant)
                    and kw.value.value is False):
                return None
        return tuple(config.donating_factories[base])
    return None


class _DonationScan:
    """Linear, per-function scan: track which locals hold donating
    callables, mark Names donated when passed at donated positions, and
    flag any later Load of a still-donated name. Loop bodies get a
    second pass so a donation late in the body is seen by reads at the
    top of the next iteration."""

    def __init__(self, mod: SourceModule, config, out: List[Finding]):
        self.mod = mod
        self.config = config
        self.out = out
        self.donating: Dict[str, Tuple[int, ...]] = {}
        self.donated: Dict[str, int] = {}    # name -> line donated at
        self.reported: Set[Tuple[int, str]] = set()

    # -- statement walk ----------------------------------------------------
    def run(self, fn: ast.FunctionDef):
        self.scan_block(fn.body)

    def scan_block(self, stmts: Sequence[ast.stmt]):
        for stmt in stmts:
            self.scan_stmt(stmt)

    def scan_stmt(self, stmt: ast.stmt):
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return   # nested scopes analyzed independently
        if isinstance(stmt, ast.Assign):
            self.scan_expr(stmt.value)
            self.handle_binding(stmt.targets, stmt.value)
        elif isinstance(stmt, ast.AnnAssign):
            if stmt.value is not None:
                self.scan_expr(stmt.value)
                self.handle_binding([stmt.target], stmt.value)
        elif isinstance(stmt, ast.AugAssign):
            self.scan_expr(stmt.value)
            self.scan_expr(stmt.target)
        elif isinstance(stmt, ast.For):
            self.scan_expr(stmt.iter)
            self.kill_targets(stmt.target)
            for _ in range(2):           # second pass: wrap-around reads
                self.scan_block(stmt.body)
            self.scan_block(stmt.orelse)
        elif isinstance(stmt, ast.While):
            for _ in range(2):
                self.scan_expr(stmt.test)
                self.scan_block(stmt.body)
            self.scan_block(stmt.orelse)
        elif isinstance(stmt, ast.If):
            self.scan_expr(stmt.test)
            # branches are alternatives; merge donated state from both
            snap = dict(self.donated)
            self.scan_block(stmt.body)
            after_body = self.donated
            self.donated = snap
            self.scan_block(stmt.orelse)
            self.donated.update(after_body)
        elif isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                self.scan_expr(item.context_expr)
                if item.optional_vars is not None:
                    self.kill_targets(item.optional_vars)
            self.scan_block(stmt.body)
        elif isinstance(stmt, ast.Try):
            self.scan_block(stmt.body)
            for h in stmt.handlers:
                self.scan_block(h.body)
            self.scan_block(stmt.orelse)
            self.scan_block(stmt.finalbody)
        elif isinstance(stmt, ast.Delete):
            for t in stmt.targets:
                if isinstance(t, ast.Name):
                    self.donated.pop(t.id, None)
        elif isinstance(stmt, (ast.Return, ast.Expr, ast.Assert,
                               ast.Raise)):
            for field in ast.iter_child_nodes(stmt):
                self.scan_expr(field)
        else:
            for field in ast.iter_child_nodes(stmt):
                if isinstance(field, ast.expr):
                    self.scan_expr(field)

    def handle_binding(self, targets, value):
        # does the RHS construct a donating callable?
        positions = None
        if isinstance(value, ast.Call):
            positions = _donating_call(self.mod, value, self.config)
        for t in targets:
            self.kill_targets(t)
            if positions is not None and isinstance(t, ast.Name):
                self.donating[t.id] = positions

    def kill_targets(self, target):
        for node in ast.walk(target):
            if isinstance(node, ast.Name):
                self.donated.pop(node.id, None)

    # -- expressions -------------------------------------------------------
    def scan_expr(self, node):
        if node is None or not isinstance(node, ast.AST):
            return
        if isinstance(node, ast.Call):
            self.scan_call(node)
            return
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
            self.check_read(node)
            return
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            return
        for child in ast.iter_child_nodes(node):
            self.scan_expr(child)

    def scan_call(self, node: ast.Call):
        self.scan_expr(node.func)
        donated_positions: Tuple[int, ...] = ()
        if isinstance(node.func, ast.Name):
            donated_positions = self.donating.get(node.func.id, ())
        else:
            # immediate call of a factory result:
            # packed_step(f)(params, opt) donates too
            if isinstance(node.func, ast.Call):
                pos = _donating_call(self.mod, node.func, self.config)
                if pos:
                    donated_positions = pos
        for i, arg in enumerate(node.args):
            self.scan_expr(arg)
            if i in donated_positions and isinstance(arg, ast.Name):
                self.donated[arg.id] = node.lineno
        for kw in node.keywords:
            self.scan_expr(kw.value)

    def check_read(self, node: ast.Name):
        line0 = self.donated.get(node.id)
        if line0 is None:
            return
        key = (node.lineno, node.id)
        if key in self.reported:
            return
        self.reported.add(key)
        self.out.append(self.mod.finding(
            "JAX101", "use-after-donate", node,
            f"`{node.id}` was donated at line {line0} (donate_argnums "
            f"buffer) — its device buffer is dead; rebind the result "
            f"instead of reading the donated local",
            context_of(self.mod, node)))


@register("JAX101", "use-after-donate",
          "no reads of locals after passing them at donated positions")
def check_use_after_donate(modules, config) -> List[Finding]:
    out: List[Finding] = []
    for mod in modules:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.FunctionDef):
                _DonationScan(mod, config, out).run(node)
    return out


@register("JAX102", "jit-in-loop",
          "no jax.jit / donating step factory constructed in a loop body")
def check_jit_in_loop(modules, config) -> List[Finding]:
    out: List[Finding] = []
    for mod in modules:
        _scan_jit_loops(mod, config, mod.tree, 0, out)
    return out


def _scan_jit_loops(mod, config, scope, loop_depth, out):
    for node in ast.iter_child_nodes(scope):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            # a def inside a loop resets the lexical hazard: the jit
            # inside only runs when the def is called
            _scan_jit_loops(mod, config, node, 0, out)
            continue
        depth = loop_depth
        if isinstance(node, (ast.For, ast.While)):
            depth += 1
        if isinstance(node, ast.Call) and loop_depth > 0:
            name = resolve_call_name(mod, node.func)
            base = (name or "").rsplit(".", 1)[-1]
            if name in _JIT_NAMES or base in config.donating_factories:
                out.append(mod.finding(
                    "JAX102", "jit-in-loop", node,
                    f"{name or base}(...) constructed inside a loop "
                    f"body retraces/recompiles every iteration — hoist "
                    f"it out or cache per static shape (the §7 "
                    f"compile-once invariant, statically)",
                    context_of(mod, node)))
        _scan_jit_loops(mod, config, node, depth, out)


# -- JAX103: Python control flow on traced parameters ------------------------

_STATIC_SAFE_CALLS = {"len", "isinstance", "hasattr", "getattr", "type"}


def _collect_jitted_defs(mod: SourceModule
                         ) -> List[Tuple[ast.FunctionDef, Set[str]]]:
    """Find local function defs that are jitted, with their traced
    parameter names (static_argnums honored when literal)."""
    defs: Dict[str, ast.FunctionDef] = {}
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.FunctionDef):
            defs[node.name] = node

    jitted: List[Tuple[ast.FunctionDef, Set[str]]] = []

    def traced_params(fn: ast.FunctionDef, static: Tuple[int, ...]
                      ) -> Set[str]:
        names = []
        for a in fn.args.posonlyargs + fn.args.args:
            names.append(a.arg)
        traced = {n for i, n in enumerate(names)
                  if i not in static and n != "self"}
        traced.update(a.arg for a in fn.args.kwonlyargs)
        return traced

    def target_def(node: ast.AST) -> Optional[ast.FunctionDef]:
        if isinstance(node, ast.Name):
            return defs.get(node.id)
        if isinstance(node, ast.Call):   # jax.jit(jax.vmap(f))
            name = resolve_call_name(mod, node.func)
            if name in _VMAP_NAMES and node.args:
                return target_def(node.args[0])
        return None

    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Call):
            name = resolve_call_name(mod, node.func)
            if name not in _JIT_NAMES or not node.args:
                continue
            fn = target_def(node.args[0])
            if fn is None:
                continue
            static: Tuple[int, ...] = ()
            for kw in node.keywords:
                if kw.arg == "static_argnums":
                    static = _literal_positions(kw.value) or ()
            jitted.append((fn, traced_params(fn, static)))
        elif isinstance(node, ast.FunctionDef):
            for dec in node.decorator_list:
                dname = resolve_call_name(
                    mod, dec.func if isinstance(dec, ast.Call) else dec)
                if dname in _JIT_NAMES:
                    static = ()
                    if isinstance(dec, ast.Call):
                        for kw in dec.keywords:
                            if kw.arg == "static_argnums":
                                static = _literal_positions(kw.value) or ()
                    jitted.append((node, traced_params(node, static)))
                elif dname in ("functools.partial",) and isinstance(
                        dec, ast.Call) and dec.args:
                    inner = resolve_call_name(mod, dec.args[0])
                    if inner in _JIT_NAMES:
                        static = ()
                        for kw in dec.keywords:
                            if kw.arg == "static_argnums":
                                static = _literal_positions(kw.value) or ()
                        jitted.append((node, traced_params(node, static)))
    return jitted


def _traced_reads_in_test(test: ast.expr, traced: Set[str]) -> List[ast.Name]:
    """Names of traced params whose VALUE the test observes. Excluded:
    `x is None` checks, attribute access (x.shape and friends are
    trace-safe), and static-safe builtin calls (len(x), isinstance)."""
    excluded: Set[int] = set()
    for node in ast.walk(test):
        if isinstance(node, ast.Compare):
            ops_ok = all(isinstance(op, (ast.Is, ast.IsNot))
                         for op in node.ops)
            comps_none = all(isinstance(c, ast.Constant)
                             and c.value is None
                             for c in node.comparators)
            if ops_ok and comps_none:
                for sub in ast.walk(node.left):
                    excluded.add(id(sub))
        elif isinstance(node, ast.Attribute):
            for sub in ast.walk(node.value):
                excluded.add(id(sub))
        elif isinstance(node, ast.Call):
            fname = node.func.id if isinstance(node.func, ast.Name) else None
            if fname in _STATIC_SAFE_CALLS:
                for arg in node.args:
                    for sub in ast.walk(arg):
                        excluded.add(id(sub))
    hits = []
    for node in ast.walk(test):
        if (isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load)
                and node.id in traced and id(node) not in excluded):
            hits.append(node)
    return hits


@register("JAX103", "traced-python-branch",
          "no Python if/while on traced parameters of jitted functions")
def check_traced_branch(modules, config) -> List[Finding]:
    out: List[Finding] = []
    seen: Set[Tuple[str, int]] = set()
    for mod in modules:
        for fn, traced in _collect_jitted_defs(mod):
            for node in _walk_fn(fn):
                if isinstance(node, (ast.If, ast.While)):
                    for read in _traced_reads_in_test(node.test, traced):
                        key = (mod.relpath, node.lineno)
                        if key in seen:
                            continue
                        seen.add(key)
                        out.append(mod.finding(
                            "JAX103", "traced-python-branch", node,
                            f"Python {'while' if isinstance(node, ast.While) else 'if'} "
                            f"on traced parameter `{read.id}` of jitted "
                            f"`{fn.name}` — use jnp.where / lax.cond / "
                            f"lax.while_loop, or mark the arg static",
                            context_of(mod, node)))
                        break
    return out


def _walk_fn(fn: ast.FunctionDef):
    """Walk a function body without descending into nested defs (their
    params shadow; they are only traced if themselves jitted)."""
    stack = list(fn.body)
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        stack.extend(ast.iter_child_nodes(node))

"""Lint driver: walk configured paths, parse, run rules, diff baseline."""
from __future__ import annotations

import dataclasses
import os
from typing import List

from repro.analysis import baseline as bl
from repro.analysis.config import LintConfig
from repro.analysis.core import (Finding, SourceModule, all_rule_ids,
                                 run_rules)


@dataclasses.dataclass
class LintResult:
    modules: List[SourceModule]
    active: List[Finding]        # findings not suppressed by pragma
    suppressed: List[Finding]
    new: List[Finding]           # active findings beyond the baseline
    stale: List[str]             # baseline fingerprints no longer found

    @property
    def ok(self) -> bool:
        return not self.new and not self.stale


def collect_files(config: LintConfig) -> List[str]:
    files: List[str] = []
    for rel in config.paths:
        path = os.path.join(config.root, rel)
        if os.path.isfile(path):
            files.append(path)
            continue
        for dirpath, dirnames, filenames in os.walk(path):
            dirnames.sort()
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    files.append(os.path.join(dirpath, fn))
    return files


def run_lint(config: LintConfig) -> LintResult:
    known = all_rule_ids()
    modules = [SourceModule.load(p, config.root, known)
               for p in collect_files(config)]
    active, suppressed, _ = run_rules(modules, config)
    base = bl.load_baseline(config.abs_baseline())
    new, stale = bl.diff_baseline(active, base)
    return LintResult(modules=modules, active=active,
                      suppressed=suppressed, new=new, stale=stale)

"""MASK — lane-mask contract rules.

The lane pool attaches/detaches jobs without recompiling; at partial
occupancy, packed kernels see dead lanes. PR 7 fixed the contract every
packed/lane-batched entrypoint owes (DESIGN.md §12):

  MASK201  the entrypoint accepts ``active=`` defaulting to None and
           branches on it (None fast path / mask passthrough) — an
           entrypoint without it silently computes garbage lanes when
           the pool hands it a partially-occupied batch;
  MASK202  every mode in ``packing.MASKED_MODES`` has a dispatcher arm
           in ``masked_pool_step`` — a registered mode with no arm is
           an unreachable execution path that tests cannot cover.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional

from repro.analysis.core import Finding, SourceModule, register


def _find_toplevel_def(tree: ast.Module, name: str
                       ) -> Optional[ast.FunctionDef]:
    for node in tree.body:
        if isinstance(node, ast.FunctionDef) and node.name == name:
            return node
    return None


def _has_active_param(fn: ast.FunctionDef) -> bool:
    """``active`` must be keyword-accepting with a default of None."""
    args = fn.args
    # positional-or-keyword with default
    pos = args.posonlyargs + args.args
    n_def = len(args.defaults)
    for i, a in enumerate(pos):
        if a.arg == "active":
            d_idx = i - (len(pos) - n_def)
            if d_idx >= 0:
                d = args.defaults[d_idx]
                return isinstance(d, ast.Constant) and d.value is None
            return False
    for a, d in zip(args.kwonlyargs, args.kw_defaults):
        if a.arg == "active":
            return isinstance(d, ast.Constant) and d.value is None
    return False


def _honors_active(fn: ast.FunctionDef) -> bool:
    """The body must actually branch on / forward the mask: an
    ``active is (not) None`` test or an ``active=...`` keyword pass-
    through to a downstream masked call."""
    for node in ast.walk(fn):
        if isinstance(node, ast.Compare):
            if (isinstance(node.left, ast.Name)
                    and node.left.id == "active"
                    and all(isinstance(op, (ast.Is, ast.IsNot))
                            for op in node.ops)
                    and all(isinstance(c, ast.Constant)
                            and c.value is None
                            for c in node.comparators)):
                return True
        if isinstance(node, ast.Call):
            for kw in node.keywords:
                if kw.arg == "active":
                    return True
    return False


@register("MASK201", "active-contract",
          "packed entrypoints accept active= with an active=None "
          "passthrough")
def check_active_contract(modules, config) -> List[Finding]:
    out: List[Finding] = []
    by_rel: Dict[str, SourceModule] = {m.relpath: m for m in modules}
    for relpath, fn_names in sorted(config.mask_entrypoints.items()):
        mod = by_rel.get(relpath)
        if mod is None:
            continue   # path filters excluded it from this run
        for fn_name in fn_names:
            fn = _find_toplevel_def(mod.tree, fn_name)
            if fn is None:
                out.append(mod.finding(
                    "MASK201", "active-contract", 1,
                    f"configured packed entrypoint `{fn_name}` not "
                    f"found at module top level — update the lint "
                    f"config if it moved"))
                continue
            if not _has_active_param(fn):
                out.append(mod.finding(
                    "MASK201", "active-contract", fn,
                    f"packed entrypoint `{fn_name}` must accept "
                    f"`active=None` (per-lane predicate; PR 7 "
                    f"contract) so the pool can hand it "
                    f"partially-occupied batches"))
            elif not _honors_active(fn):
                out.append(mod.finding(
                    "MASK201", "active-contract", fn,
                    f"`{fn_name}` takes `active=` but never branches "
                    f"on it (no `active is None` fast path, no "
                    f"`active=` passthrough) — the mask is ignored"))
    return out


@register("MASK202", "mode-dispatch",
          "every MASKED_MODES member has a dispatcher branch")
def check_mode_dispatch(modules, config) -> List[Finding]:
    out: List[Finding] = []
    spec = config.mask_dispatch
    if not spec:
        return out
    mod = next((m for m in modules if m.relpath == spec["module"]), None)
    if mod is None:
        return out

    modes = None
    const_node = None
    for node in mod.tree.body:
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if (isinstance(t, ast.Name)
                        and t.id == spec["modes_const"]):
                    const_node = node
                    if isinstance(node.value, (ast.Tuple, ast.List)):
                        vals = []
                        for el in node.value.elts:
                            if (isinstance(el, ast.Constant)
                                    and isinstance(el.value, str)):
                                vals.append(el.value)
                        modes = vals
    if modes is None:
        out.append(mod.finding(
            "MASK202", "mode-dispatch", 1,
            f"could not statically read {spec['modes_const']} (must be "
            f"a literal tuple of strings)"))
        return out

    fn = _find_toplevel_def(mod.tree, spec["dispatcher"])
    if fn is None:
        out.append(mod.finding(
            "MASK202", "mode-dispatch", const_node,
            f"dispatcher `{spec['dispatcher']}` not found"))
        return out

    param = spec["param"]
    handled = set()
    for node in ast.walk(fn):
        if not isinstance(node, ast.Compare):
            continue
        sides = [node.left] + list(node.comparators)
        names = [s for s in sides if isinstance(s, ast.Name)]
        consts = [s for s in sides if isinstance(s, ast.Constant)
                  and isinstance(s.value, str)]
        if any(n.id == param for n in names):
            for c in consts:
                handled.add(c.value)
    for mode in modes:
        if mode not in handled:
            out.append(mod.finding(
                "MASK202", "mode-dispatch", fn,
                f"mode {mode!r} is registered in "
                f"{spec['modes_const']} but `{spec['dispatcher']}` has "
                f"no `{param} == {mode!r}` branch — unreachable "
                f"execution mode"))
    return out

"""DET — determinism rules for decision-path modules.

The scheduler-quality gate (DESIGN.md §11) compares replay metrics
EXACTLY against BENCH_HISTORY.json; the preemption/repack seams promise
bit-identical resumes. Both only hold while every scheduling decision is
a pure function of recorded inputs. Each rule here bans one way real
nondeterminism has historically crept into such systems:

  DET001  any clock read on the decision path
  DET002  wall-clock used as a duration clock anywhere in src/
  DET003  unseeded RNG on the decision path
  DET004  iteration over a set feeding order-sensitive consumers
  DET005  id()-derived ordering / keying
  DET006  float == / != in scheduling gates
"""
from __future__ import annotations

import ast
from typing import Iterable, List

from repro.analysis.core import (Finding, SourceModule, context_of,
                                 register, resolve_call_name)

# every clock in the stdlib that can observe the host at run time
_ALL_CLOCKS = {
    "time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns", "time.process_time",
    "time.process_time_ns", "time.clock_gettime", "time.localtime",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.date.today",
}

# clocks that read the WALL (drift under NTP/suspend): never the right
# duration clock; time.perf_counter is the sanctioned one outside
# decision modules
_WALL_CLOCKS = {
    "time.time", "time.time_ns", "time.localtime",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.date.today",
}

_NP_LEGACY_RNG = {
    "rand", "randn", "randint", "random", "random_sample", "ranf",
    "sample", "choice", "shuffle", "permutation", "uniform", "normal",
    "standard_normal", "beta", "binomial", "poisson", "exponential",
    "gamma", "lognormal", "pareto", "seed", "bytes", "random_integers",
}

_NP_BITGENS = {
    "numpy.random.default_rng", "numpy.random.Philox",
    "numpy.random.PCG64", "numpy.random.PCG64DXSM",
    "numpy.random.MT19937", "numpy.random.SFC64",
    "numpy.random.Generator",
}


def _decision_mods(modules, config) -> Iterable[SourceModule]:
    for mod in modules:
        if config.is_decision(mod.relpath):
            yield mod


@register("DET001", "wall-clock-decision",
          "no clock reads inside decision-path modules")
def check_clock_decision(modules, config) -> List[Finding]:
    out: List[Finding] = []
    for mod in _decision_mods(modules, config):
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            name = resolve_call_name(mod, node.func)
            if name in _ALL_CLOCKS:
                out.append(mod.finding(
                    "DET001", "wall-clock-decision", node,
                    f"{name}() read inside decision-path module — a "
                    f"decision must be a pure function of recorded "
                    f"inputs; pragma telemetry-only reads with a reason",
                    context_of(mod, node)))
    return out


@register("DET002", "wall-clock-timing",
          "wall clock is never the duration clock; use time.perf_counter")
def check_wall_clock(modules, config) -> List[Finding]:
    out: List[Finding] = []
    for mod in modules:
        if config.is_decision(mod.relpath):
            continue   # DET001 already bans every clock there
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            name = resolve_call_name(mod, node.func)
            if name in _WALL_CLOCKS:
                out.append(mod.finding(
                    "DET002", "wall-clock-timing", node,
                    f"{name}() reads the wall clock — every other layer "
                    f"times with time.perf_counter(); unify (wall-clock "
                    f"timestamps drift under NTP/suspend)",
                    context_of(mod, node)))
    return out


@register("DET003", "unseeded-rng",
          "no unseeded randomness inside decision-path modules")
def check_unseeded_rng(modules, config) -> List[Finding]:
    out: List[Finding] = []
    for mod in _decision_mods(modules, config):
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            name = resolve_call_name(mod, node.func)
            if name is None:
                continue
            bad = None
            if name.startswith("random."):
                bad = ("stdlib random module is globally seeded mutable "
                       "state")
            elif (name.startswith("numpy.random.")
                  and name.rsplit(".", 1)[-1] in _NP_LEGACY_RNG):
                bad = "legacy numpy global RNG is shared mutable state"
            elif name in _NP_BITGENS and not node.args and not node.keywords:
                bad = ("bit generator constructed without an explicit "
                       "seed/key draws OS entropy")
            if bad:
                out.append(mod.finding(
                    "DET003", "unseeded-rng", node,
                    f"{name}() on the decision path: {bad}; thread an "
                    f"explicit seeded Generator (traces.py pattern: "
                    f"np.random.Generator(np.random.Philox(key=seed)))",
                    context_of(mod, node)))
    return out


# -- DET004: set iteration ---------------------------------------------------

_ORDER_SENSITIVE_WRAPPERS = {"list", "tuple", "enumerate", "iter", "next",
                             "reversed", "map", "filter", "zip"}

_SET_METHODS = {"union", "intersection", "difference",
                "symmetric_difference", "copy"}


class _SetTyping:
    """Best-effort, flow-insensitive inference of set-typed names within
    one scope (nested defs inherit the parent's typing)."""

    def __init__(self, parent_names=()):
        self.set_names = set(parent_names)

    def is_set_expr(self, node: ast.AST) -> bool:
        if isinstance(node, (ast.Set, ast.SetComp)):
            return True
        if isinstance(node, ast.Call):
            fn = node.func
            if isinstance(fn, ast.Name) and fn.id in ("set", "frozenset"):
                return True
            if (isinstance(fn, ast.Attribute) and fn.attr in _SET_METHODS
                    and self.is_set_expr(fn.value)):
                return True
        if isinstance(node, ast.BinOp) and isinstance(
                node.op, (ast.BitOr, ast.BitAnd, ast.Sub, ast.BitXor)):
            return (self.is_set_expr(node.left)
                    or self.is_set_expr(node.right))
        if isinstance(node, ast.Name):
            return node.id in self.set_names
        return False

    def learn(self, stmt: ast.stmt):
        if isinstance(stmt, ast.Assign):
            targets, value = stmt.targets, stmt.value
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            targets, value = [stmt.target], stmt.value
        elif isinstance(stmt, ast.AugAssign):
            # s |= {...} keeps set typing; anything else learns nothing
            if self.is_set_expr(stmt.value) and isinstance(
                    stmt.target, ast.Name):
                self.set_names.add(stmt.target.id)
            return
        else:
            return
        if self.is_set_expr(value):
            for t in targets:
                if isinstance(t, ast.Name):
                    self.set_names.add(t.id)
        else:
            for t in targets:
                if isinstance(t, ast.Name):
                    self.set_names.discard(t.id)


def _walk_scope(scope_node):
    """Walk a module/def body without descending into nested defs."""
    if isinstance(scope_node, ast.Lambda):
        roots = [scope_node.body]
    else:
        roots = list(getattr(scope_node, "body", []))
    stack = list(roots)
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        stack.extend(ast.iter_child_nodes(node))


@register("DET004", "set-iteration",
          "no iteration over sets feeding order-sensitive consumers")
def check_set_iteration(modules, config) -> List[Finding]:
    out: List[Finding] = []
    for mod in _decision_mods(modules, config):
        _scan_set_scope(mod, mod.tree, _SetTyping(), out)
    return out


def _scan_set_scope(mod: SourceModule, scope_node, parent: _SetTyping,
                    out: List[Finding]):
    typing = _SetTyping(parent.set_names)
    for sub in _walk_scope(scope_node):
        if isinstance(sub, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            typing.learn(sub)
    for sub in _walk_scope(scope_node):
        _flag_set_iter(mod, sub, typing, out)
    for sub in _walk_scope(scope_node):
        if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.Lambda)):
            _scan_set_scope(mod, sub, typing, out)


def _flag_set_iter(mod, node, typing: _SetTyping, out: List[Finding]):
    hits = []
    if isinstance(node, ast.For) and typing.is_set_expr(node.iter):
        hits.append(node.iter)
    elif isinstance(node, (ast.ListComp, ast.GeneratorExp, ast.DictComp)):
        # SetComp over a set stays order-insensitive; these three leak
        # the iteration order into an ordered container / consumer
        for gen in node.generators:
            if typing.is_set_expr(gen.iter):
                hits.append(gen.iter)
    elif isinstance(node, ast.Call):
        fn = node.func
        if isinstance(fn, ast.Name) and fn.id in _ORDER_SENSITIVE_WRAPPERS:
            for arg in node.args:
                if typing.is_set_expr(arg):
                    hits.append(arg)
    for h in hits:
        out.append(mod.finding(
            "DET004", "set-iteration", h,
            "iterating a set here leaks hash order into an "
            "order-sensitive consumer — wrap in sorted(...); order-"
            "insensitive reductions (min/max/sum/any/all/set) are fine",
            context_of(mod, h)))


@register("DET005", "id-ordering",
          "no id()-derived ordering or keying on the decision path")
def check_id_ordering(modules, config) -> List[Finding]:
    out: List[Finding] = []
    for mod in _decision_mods(modules, config):
        if "id" in mod.import_aliases:
            continue   # shadowed by an import; not the builtin
        for node in ast.walk(mod.tree):
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name)
                    and node.func.id == "id"):
                out.append(mod.finding(
                    "DET005", "id-ordering", node,
                    "id() is a memory address — any ordering/keying "
                    "derived from it varies run to run; key on a stable "
                    "field (job id, submit_seq) instead",
                    context_of(mod, node)))
    return out


@register("DET006", "float-eq-gate",
          "no float == / != in scheduling gates")
def check_float_eq(modules, config) -> List[Finding]:
    out: List[Finding] = []
    for mod in _decision_mods(modules, config):
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Compare):
                continue
            if not any(isinstance(op, (ast.Eq, ast.NotEq))
                       for op in node.ops):
                continue
            operands = [node.left] + list(node.comparators)
            if any(isinstance(o, ast.Constant)
                   and isinstance(o.value, float) for o in operands):
                out.append(mod.finding(
                    "DET006", "float-eq-gate", node,
                    "float equality in a decision gate — accumulated "
                    "float state is platform/order sensitive; compare "
                    "with an explicit tolerance or gate on the integer "
                    "event that set the value",
                    context_of(mod, node)))
    return out

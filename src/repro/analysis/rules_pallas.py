"""PAL rule family: pallas_call kernel-contract checks.

PR 8's rule families police the *Python entrypoint* layer (masking
dispatch, donation, determinism). These rules police the layer
underneath — the ``pallas_call`` itself — where the real hazards live:
an accumulator scratch without its init guard double-counts across
grid steps, an index map whose arity drifts from the grid silently
reads the wrong tiles, and masking applied after the kernel (where-
zero) burns MXU cycles the lane predicate was supposed to save.

Catalog (details in DESIGN.md §14):

  PAL401  index-map arity: lambda params == grid rank, and the map's
          output tuple arity == the BlockSpec's block-shape rank.
  PAL402  index-map prunability: flag non-affine maps. Classification
          (affine / affine_div / non_affine) also feeds the pruning-
          readiness report (kernel_report.py) that ROADMAP 3(b)'s
          scalar-prefetch grid pruning consumes.
  PAL403  lane masking must reach the kernel: every kernel registered
          in ``MASKED_KERNELS`` must gate its dot/einsum ops (or, for
          dot-free kernels, its ref writes) behind ``pl.when`` on an
          SMEM lane-predicate read. Post-hoc where-zero does not count.
  PAL404  accumulator discipline: scratch updated from itself needs a
          ``pl.when(k == 0)`` init guard, and a direct scratch emit
          into an output ref must sit under ``pl.when(k == nk - 1)``.
  PAL405  dimension_semantics arity == grid rank, and every grid axis
          appearing in an accumulator guard must be "arbitrary".
  PAL406  tile-traffic drift: per-grid-step HBM bytes computed from the
          block shapes (f32 model) must match the registered budget in
          ``roofline.hlo_costs.PALLAS_TILE_BUDGETS`` within tolerance.
"""
from __future__ import annotations

import ast
from typing import Dict, List

from repro.analysis import pallas_model as pm
from repro.analysis.core import SourceModule, register


def _models(mod: SourceModule, config) -> List[pm.PallasCallModel]:
    return pm.extract_pallas_calls(
        mod, config.tile_nominal_dims.get(mod.relpath, {}))


def _by_relpath(modules) -> Dict[str, SourceModule]:
    return {m.relpath: m for m in modules}


@register("PAL401", "pallas-index-map-arity",
          "index-map params must match grid rank; output arity must "
          "match block-shape rank")
def rule_pal401(modules, config):
    findings = []
    for mod in modules:
        for m in _models(mod, config):
            if m.grid_rank is None:
                findings.append(mod.finding(
                    "PAL401", "pallas-index-map-arity", m.lineno,
                    f"pallas_call in `{m.entry}` has no statically "
                    "resolvable grid — keep `grid=` a literal tuple (or "
                    "a local assigned one) so arity checks can run",
                    context=m.entry))
                continue
            for spec in m.specs:
                im = spec.index_map
                if im is None:
                    continue
                where = f"{spec.role}_specs[{spec.position}]"
                if len(im.params) != m.grid_rank:
                    findings.append(mod.finding(
                        "PAL401", "pallas-index-map-arity", im.lineno,
                        f"`{m.entry}` {where}: index map takes "
                        f"{len(im.params)} grid indices but the grid has "
                        f"rank {m.grid_rank}", context=m.entry))
                if (spec.block_shape is not None
                        and len(im.exprs) != len(spec.block_shape)):
                    findings.append(mod.finding(
                        "PAL401", "pallas-index-map-arity", im.lineno,
                        f"`{m.entry}` {where}: index map returns "
                        f"{len(im.exprs)} coordinates but the block shape "
                        f"has rank {len(spec.block_shape)}",
                        context=m.entry))
    return findings


@register("PAL402", "pallas-index-map-prunable",
          "index maps must stay affine (or affine-with-div) in the grid "
          "indices so scalar-prefetch pruning stays possible")
def rule_pal402(modules, config):
    findings = []
    for mod in modules:
        for m in _models(mod, config):
            for spec in m.specs:
                im = spec.index_map
                if im is None or im.classification != pm.NON_AFFINE:
                    continue
                bad = [e for e, c in zip(im.exprs, im.classes)
                       if c == pm.NON_AFFINE]
                findings.append(mod.finding(
                    "PAL402", "pallas-index-map-prunable", im.lineno,
                    f"`{m.entry}` {spec.role}_specs[{spec.position}]: "
                    f"index map element(s) {', '.join(bad)} are not "
                    "affine in the grid indices — this block cannot be "
                    "pruned by scalar-prefetch index rewriting "
                    "(ROADMAP 3b)", context=m.entry))
    return findings


@register("PAL403", "pallas-lane-mask-native",
          "MASKED_KERNELS pallas kernels must gate accumulate/dot work "
          "behind pl.when on an SMEM lane predicate")
def rule_pal403(modules, config):
    findings = []
    by_rel = _by_relpath(modules)
    for relpath in sorted(config.masked_kernels):
        mod = by_rel.get(relpath)
        if mod is None:
            continue
        models = _models(mod, config)
        for entry in config.masked_kernels[relpath]:
            entry_models = [m for m in models if m.entry == entry]
            if not entry_models:
                findings.append(mod.finding(
                    "PAL403", "pallas-lane-mask-native", 1,
                    f"MASKED_KERNELS registers `{entry}` but no "
                    "pallas_call site was found in that function — "
                    "update repro.analysis.config", context=entry))
                continue
            for m in entry_models:
                bodies = [pm.analyze_kernel(mod, k, len(m.out_specs),
                                            m.n_scratch)
                          for k in m.kernel_names]
                bodies = [b for b in bodies if b is not None]
                if any(pm.kernel_is_lane_gated(mod, b) for b in bodies):
                    continue
                findings.append(mod.finding(
                    "PAL403", "pallas-lane-mask-native", m.lineno,
                    f"`{entry}` has no kernel variant gating its "
                    "compute behind pl.when on an SMEM lane predicate — "
                    "inactive lanes still issue MXU work (post-hoc "
                    "where-zero does not count; see packed_gemm."
                    "_pg_masked_kernel for the pattern)",
                    context=entry))
    return findings


@register("PAL404", "pallas-accumulator-guards",
          "accumulator scratch needs pl.when(k==0) init; direct scratch "
          "emits into outputs need pl.when(k==nk-1)")
def rule_pal404(modules, config):
    findings = []
    for mod in modules:
        seen = set()
        for m in _models(mod, config):
            for kname in m.kernel_names:
                if kname in seen:
                    continue
                seen.add(kname)
                body = pm.analyze_kernel(mod, kname, len(m.out_specs),
                                         m.n_scratch)
                if body is None:
                    continue
                n_pos = len(body.params)
                n_out = len(m.out_specs)
                outs = set(body.params[n_pos - m.n_scratch - n_out:
                                       n_pos - m.n_scratch])

                for s in sorted(body.accumulated):
                    inited = any(
                        g.kind == "zero" and any(
                            isinstance(t, ast.Subscript)
                            and isinstance(t.value, ast.Name)
                            and t.value.id == s
                            for n in ast.walk(g.node)
                            if isinstance(n, ast.Assign)
                            for t in n.targets)
                        for g in body.guards)
                    if not inited:
                        findings.append(mod.finding(
                            "PAL404", "pallas-accumulator-guards",
                            body.node.lineno,
                            f"kernel `{kname}`: accumulator scratch "
                            f"`{s}` is updated from itself but never "
                            "zero-initialised under pl.when(k == 0) — "
                            "it carries garbage across grid steps",
                            context=kname))

                # direct scratch emits into output refs must be guarded
                last_nodes = set()
                for g in body.guards:
                    if g.kind == "last":
                        for n in ast.walk(g.node):
                            last_nodes.add(id(n))
                for node in ast.walk(body.node):
                    if not (isinstance(node, ast.Assign)
                            and len(node.targets) == 1):
                        continue
                    t = node.targets[0]
                    if not (isinstance(t, ast.Subscript)
                            and isinstance(t.value, ast.Name)
                            and t.value.id in outs):
                        continue
                    reads = pm._subscript_reads(node.value,
                                                body.accumulated)
                    if reads and id(node) not in last_nodes:
                        findings.append(mod.finding(
                            "PAL404", "pallas-accumulator-guards",
                            node.lineno,
                            f"kernel `{kname}`: output ref "
                            f"`{t.value.id}` is written from accumulator "
                            f"scratch {sorted(reads)} outside a "
                            "pl.when(k == nk - 1) guard — partial sums "
                            "escape on every grid step",
                            context=kname))
    return findings


@register("PAL405", "pallas-dimension-semantics",
          "dimension_semantics arity must match grid rank; accumulation "
          "axes must be declared \"arbitrary\"")
def rule_pal405(modules, config):
    findings = []
    for mod in modules:
        for m in _models(mod, config):
            sem = m.dimension_semantics
            if sem is None or m.grid_rank is None:
                continue
            if len(sem) != m.grid_rank:
                findings.append(mod.finding(
                    "PAL405", "pallas-dimension-semantics", m.lineno,
                    f"`{m.entry}`: dimension_semantics has "
                    f"{len(sem)} entries but the grid has rank "
                    f"{m.grid_rank}", context=m.entry))
                continue
            axes = set()
            for kname in m.kernel_names:
                body = pm.analyze_kernel(mod, kname, len(m.out_specs),
                                         m.n_scratch)
                if body is None or not body.accumulated:
                    continue
                for g in body.guards:
                    if g.kind in ("zero", "last"):
                        axes.update(g.axes)
            for axis in sorted(axes):
                if axis < len(sem) and sem[axis] != "arbitrary":
                    findings.append(mod.finding(
                        "PAL405", "pallas-dimension-semantics", m.lineno,
                        f"`{m.entry}`: grid axis {axis} carries scratch "
                        f"accumulation but dimension_semantics declares "
                        f"it \"{sem[axis]}\" — a parallel axis may "
                        "execute out of order and corrupt the "
                        "accumulator", context=m.entry))
    return findings


@register("PAL406", "pallas-tile-traffic-budget",
          "per-grid-step HBM bytes from block shapes must match the "
          "registered roofline budget within tolerance")
def rule_pal406(modules, config):
    findings = []
    for mod in modules:
        for m in _models(mod, config):
            budget = config.tile_budgets.get(m.key)
            if budget is None:
                findings.append(mod.finding(
                    "PAL406", "pallas-tile-traffic-budget", m.lineno,
                    f"`{m.entry}`: no tile-traffic budget registered — "
                    f"add \"{m.key}\" to roofline.hlo_costs."
                    "PALLAS_TILE_BUDGETS (register before you build)",
                    context=m.entry))
                continue
            total, unresolved = m.bytes_per_step()
            if total is None:
                findings.append(mod.finding(
                    "PAL406", "pallas-tile-traffic-budget", m.lineno,
                    f"`{m.entry}`: block dims {list(unresolved)} are not "
                    "statically resolvable — add nominal sizes to "
                    "roofline.hlo_costs.PALLAS_NOMINAL_DIMS",
                    context=m.entry))
                continue
            tol = config.tile_tolerance
            if abs(total - budget) > tol * budget:
                findings.append(mod.finding(
                    "PAL406", "pallas-tile-traffic-budget", m.lineno,
                    f"`{m.entry}`: modeled tile traffic "
                    f"{total:.0f} B/step drifts from the registered "
                    f"budget {budget:.0f} B/step by more than "
                    f"{tol:.0%} — re-derive the BlockSpecs or update "
                    "PALLAS_TILE_BUDGETS alongside the kernel change",
                    context=m.entry))
    return findings

"""Pruning-readiness report over every pallas_call site (DESIGN.md §14).

    PYTHONPATH=src python -m repro.analysis.kernel_report           # table
    PYTHONPATH=src python -m repro.analysis.kernel_report --json
    PYTHONPATH=src python -m repro.analysis.kernel_report --check   # CI gate

The JSON report is the machine-readable contract ROADMAP 3(b)'s
scalar-prefetch grid pruning consumes: per kernel, which index maps
are affine (rewritable to a prefetched index vector), which are
affine-with-div (prunable with a gather), whether the kernel already
carries a lane predicate, and the modeled HBM bytes per grid step. A
kernel is marked ``prunable`` when it is lane-gated AND every input
index map is statically rewritable — exactly the precondition for
skipping inactive tiles' HBM streams.

``--check`` is the CI gate: it re-runs the full lint (dep-free, AST
only) and fails on any PAL-family finding that is not tolerated by the
committed baseline, so the report and the gate can never disagree.
Exit status: 0 clean, 1 contract drift, 2 usage errors.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List

from repro.analysis import pallas_model as pm
from repro.analysis.config import LintConfig, default_config
from repro.analysis.core import SourceModule, all_rule_ids
from repro.analysis.driver import collect_files, run_lint

REPORT_VERSION = 1


def _spec_entry(spec: pm.SpecModel) -> Dict:
    entry: Dict = {
        "role": spec.role,
        "position": spec.position,
        "block_shape": list(spec.block_shape) if spec.block_shape else None,
        "block_elems": spec.block_elems,
        "memory_space": spec.memory_space,
        "conditional": spec.conditional,
    }
    if spec.index_map is None:
        entry["index_map"] = None
    else:
        im = spec.index_map
        entry["index_map"] = {
            "params": list(im.params),
            "exprs": list(im.exprs),
            "classes": list(im.classes),
            "classification": im.classification,
        }
    return entry


def _kernel_entry(mod: SourceModule, m: pm.PallasCallModel,
                  config: LintConfig) -> Dict:
    bodies = [pm.analyze_kernel(mod, k, len(m.out_specs), m.n_scratch)
              for k in m.kernel_names]
    bodies = [b for b in bodies if b is not None]
    lane = any(pm.kernel_is_lane_gated(mod, b) for b in bodies)
    bytes_per_step, unresolved = m.bytes_per_step()
    in_maps = [s.index_map for s in m.in_specs if s.index_map is not None]
    rewritable = all(im.classification in (pm.AFFINE, pm.AFFINE_DIV)
                     for im in in_maps)
    return {
        "path": m.relpath,
        "entry": m.entry,
        "line": m.lineno,
        "grid": list(m.grid_exprs),
        "grid_rank": m.grid_rank,
        "dimension_semantics": (list(m.dimension_semantics)
                                if m.dimension_semantics else None),
        "kernels": list(m.kernel_names),
        "lane_predicate": lane,
        "scratch": list(m.scratch_exprs),
        "operands": [_spec_entry(s) for s in m.specs],
        "bytes_per_grid_step": bytes_per_step,
        "unresolved_dims": list(unresolved),
        "tile_budget": config.tile_budgets.get(m.key),
        "prunable": bool(lane and rewritable),
    }


def build_report(config: LintConfig) -> Dict:
    """The full pruning-readiness report as a JSON-serialisable dict.
    Deterministic: files come from the sorted walk, kernels are in
    source order within a file."""
    known = all_rule_ids()
    kernels: List[Dict] = []
    for path in collect_files(config):
        mod = SourceModule.load(path, config.root, known)
        nominal = config.tile_nominal_dims.get(mod.relpath, {})
        for m in pm.extract_pallas_calls(mod, nominal):
            kernels.append(_kernel_entry(mod, m, config))
    return {
        "version": REPORT_VERSION,
        "paths": list(config.paths),
        "kernels": kernels,
        "n_kernels": len(kernels),
        "n_prunable": sum(1 for k in kernels if k["prunable"]),
    }


def _format_table(rep: Dict) -> str:
    lines = []
    for k in rep["kernels"]:
        classes = sorted({s["index_map"]["classification"]
                          for s in k["operands"] if s["index_map"]})
        lines.append(
            f"{k['path']}:{k['line']}: {k['entry']} "
            f"grid={k['grid_rank']} lane_predicate={k['lane_predicate']} "
            f"maps={'/'.join(classes) or '-'} "
            f"bytes/step={k['bytes_per_grid_step'] or '?'} "
            f"prunable={k['prunable']}")
    lines.append(f"kernel_report: {rep['n_kernels']} pallas_call site(s), "
                 f"{rep['n_prunable']} prunable")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.kernel_report",
        description="static pruning-readiness report over every "
                    "pallas_call site")
    ap.add_argument("--root", default=None,
                    help="checkout root (default: derived from the "
                         "package location)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable report on stdout")
    ap.add_argument("--out", default=None,
                    help="also write the JSON report to this file")
    ap.add_argument("--check", action="store_true",
                    help="gate mode: exit 1 on any PAL finding not "
                         "tolerated by the committed baseline")
    args = ap.parse_args(argv)

    try:
        config = default_config(root=args.root)
        rep = build_report(config)
    except (OSError, SyntaxError, ValueError) as e:
        print(f"kernel_report: error: {e}", file=sys.stderr)
        return 2

    text = json.dumps(rep, indent=2, sort_keys=True)
    if args.out:
        with open(args.out, "w", encoding="utf-8") as f:
            f.write(text + "\n")

    if args.check:
        result = run_lint(config)
        pal_new = [f for f in result.new if f.rule.startswith("PAL")]
        pal_stale = [fp for fp in result.stale if fp.startswith("PAL")]
        for f in pal_new:
            print(f.render())
        for fp in pal_stale:
            print(f"kernel_report: stale baseline entry (fixed but "
                  f"shrink not committed): {fp}")
        ok = not pal_new and not pal_stale
        print(f"kernel_report: {rep['n_kernels']} pallas_call site(s), "
              f"{rep['n_prunable']} prunable, "
              f"{len(pal_new)} new PAL finding(s)"
              + (" — clean" if ok else ""))
        return 0 if ok else 1

    if args.as_json:
        print(text)
    else:
        print(_format_table(rep))
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Human and machine rendering of a lint run."""
from __future__ import annotations

import collections
import json
from typing import List, Sequence

from repro.analysis.core import Finding


def format_findings(findings: Sequence[Finding]) -> str:
    return "\n".join(f.render() for f in findings)


def summary_line(active: Sequence[Finding], suppressed: Sequence[Finding],
                 n_files: int) -> str:
    by_rule = collections.Counter(f.rule for f in active)
    detail = ", ".join(f"{r}×{n}" for r, n in sorted(by_rule.items()))
    head = (f"{len(active)} finding(s) in {n_files} file(s)"
            if active else f"clean: 0 findings in {n_files} file(s)")
    if detail:
        head += f" [{detail}]"
    if suppressed:
        head += f" ({len(suppressed)} suppressed by pragma)"
    return head


def to_json(active: Sequence[Finding], suppressed: Sequence[Finding],
            new: Sequence[Finding], stale: Sequence[str],
            n_files: int) -> str:
    def row(f: Finding) -> dict:
        return {"rule": f.rule, "name": f.name, "path": f.path,
                "line": f.line, "context": f.context,
                "message": f.message, "fingerprint": f.fingerprint}
    return json.dumps({
        "files": n_files,
        "active": [row(f) for f in active],
        "suppressed": [row(f) for f in suppressed],
        "new": [row(f) for f in new],
        "stale_baseline": list(stale),
    }, indent=1)


def rule_catalog(rules) -> str:
    lines: List[str] = []
    for rule in sorted(rules.values(), key=lambda r: r.id):
        lines.append(f"{rule.id}  {rule.name:24s} {rule.doc}")
    lines.append("LNT001  malformed-pragma         pragmas need "
                 "`RULE(reason)` with a non-empty reason")
    lines.append("LNT002  unused-pragma            pragmas that suppress "
                 "nothing must be deleted")
    return "\n".join(lines)

"""Lint framework core: findings, pragmas, rule registry, module model.

A *finding* is one rule violation anchored to a file/line. Its
``fingerprint`` intentionally omits the line *number* (it keys on the
enclosing scope plus the normalized source text) so the committed
baseline survives unrelated edits above a tolerated finding.

Suppression is per-line and must carry a reason::

    t0 = time.perf_counter()  # lint: disable=DET001(telemetry only)

A pragma with no reason, an unknown rule id, or a pragma that suppresses
nothing is itself a finding (LNT001 / LNT002) — stale suppressions rot
into blind spots otherwise.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from typing import Callable, Dict, List, Optional, Sequence, Tuple

# --------------------------------------------------------------------------
# findings
# --------------------------------------------------------------------------

_WS = re.compile(r"\s+")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""
    rule: str                 # e.g. "DET001"
    name: str                 # mnemonic, e.g. "wall-clock-decision"
    path: str                 # root-relative, forward slashes
    line: int                 # 1-based physical line of the anchor node
    message: str
    context: str = "<module>"  # enclosing def/class qualname
    line_text: str = ""        # stripped source of the anchor line

    @property
    def fingerprint(self) -> str:
        """Line-number-free identity used by the baseline file."""
        norm = _WS.sub(" ", self.line_text.strip())
        return f"{self.rule}|{self.path}|{self.context}|{norm}"

    def render(self) -> str:
        return (f"{self.path}:{self.line}: {self.rule}[{self.name}] "
                f"{self.message}")


# --------------------------------------------------------------------------
# pragmas
# --------------------------------------------------------------------------

PRAGMA_RE = re.compile(r"#\s*lint:\s*disable=(?P<entries>.+?)\s*$")
PRAGMA_ENTRY_RE = re.compile(r"(?P<rule>[A-Z]{3}\d{3})\((?P<reason>[^()]*)\)")
PRAGMA_TOKEN_RE = re.compile(r"[A-Z]{3}\d{3}")


@dataclasses.dataclass
class Pragma:
    """One ``RULE(reason)`` suppression entry on one line."""
    line: int
    rule: str
    reason: str
    used: bool = False


def _comment_tokens(source: str) -> List[Tuple[int, str]]:
    """(line, comment_text) for every real COMMENT token — pragma text
    inside string literals/docstrings must not count."""
    import io
    import tokenize
    out: List[Tuple[int, str]] = []
    try:
        for tok in tokenize.generate_tokens(io.StringIO(source).readline):
            if tok.type == tokenize.COMMENT:
                out.append((tok.start[0], tok.string))
    except (tokenize.TokenError, IndentationError):
        pass   # ast.parse already vets syntax; partial scans are fine
    return out


def parse_pragmas(source: str,
                  known_rules: Optional[set] = None,
                  ) -> Tuple[List[Pragma], List[Tuple[int, str]]]:
    """Scan comment tokens for suppression pragmas.

    Returns ``(pragmas, malformed)`` where ``malformed`` is a list of
    ``(line, problem)`` — entries with an empty reason, bare rule tokens
    without a ``(reason)``, or unknown rule ids.
    """
    pragmas: List[Pragma] = []
    malformed: List[Tuple[int, str]] = []
    for i, text in _comment_tokens(source):
        m = PRAGMA_RE.search(text)
        if not m:
            continue
        entries = m.group("entries")
        seen_spans = []
        for em in PRAGMA_ENTRY_RE.finditer(entries):
            seen_spans.append(em.span())
            rule, reason = em.group("rule"), em.group("reason").strip()
            if not reason:
                malformed.append(
                    (i, f"pragma for {rule} has an empty reason"))
                continue
            if known_rules is not None and rule not in known_rules:
                malformed.append((i, f"pragma names unknown rule {rule}"))
                continue
            pragmas.append(Pragma(line=i, rule=rule, reason=reason))
        # bare rule tokens outside any RULE(reason) span lack a reason
        for tm in PRAGMA_TOKEN_RE.finditer(entries):
            if not any(s <= tm.start() < e for s, e in seen_spans):
                malformed.append(
                    (i, f"pragma for {tm.group(0)} is missing a "
                        f"(reason)"))
    return pragmas, malformed


# --------------------------------------------------------------------------
# source module model
# --------------------------------------------------------------------------

@dataclasses.dataclass
class SourceModule:
    """A parsed source file plus everything rules need to inspect it."""
    path: str                       # absolute
    relpath: str                    # root-relative, forward slashes
    source: str
    lines: List[str]
    tree: ast.Module
    pragmas: List[Pragma]
    malformed_pragmas: List[Tuple[int, str]]
    import_aliases: Dict[str, str]  # local name -> canonical dotted prefix

    @classmethod
    def load(cls, path, root, known_rules: Optional[set] = None
             ) -> "SourceModule":
        import os
        with open(path, "r", encoding="utf-8") as f:
            source = f.read()
        rel = os.path.relpath(path, root).replace(os.sep, "/")
        tree = ast.parse(source, filename=rel)
        lines = source.splitlines()
        pragmas, malformed = parse_pragmas(source, known_rules)
        return cls(path=str(path), relpath=rel, source=source, lines=lines,
                   tree=tree, pragmas=pragmas, malformed_pragmas=malformed,
                   import_aliases=collect_import_aliases(tree))

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""

    def finding(self, rule: str, name: str, node_or_line, message: str,
                context: str = "<module>") -> Finding:
        line = (node_or_line if isinstance(node_or_line, int)
                else getattr(node_or_line, "lineno", 1))
        return Finding(rule=rule, name=name, path=self.relpath, line=line,
                       message=message, context=context,
                       line_text=self.line_text(line))


def collect_import_aliases(tree: ast.Module) -> Dict[str, str]:
    """Map local names to canonical dotted prefixes.

    ``import numpy as np`` -> {"np": "numpy"};
    ``from time import perf_counter as pc`` -> {"pc": "time.perf_counter"}.
    Star imports are ignored (unresolvable statically).
    """
    aliases: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                aliases[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom):
            if node.module is None or node.level:
                continue   # relative imports: keep local resolution only
            for a in node.names:
                if a.name == "*":
                    continue
                aliases[a.asname or a.name] = f"{node.module}.{a.name}"
    return aliases


def dotted_name(node: ast.AST) -> Optional[str]:
    """Render a Name/Attribute chain as ``a.b.c`` (None if not a chain)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def resolve_call_name(mod: SourceModule, func: ast.AST) -> Optional[str]:
    """Canonical dotted name of a call target, expanding import aliases.

    ``np.random.default_rng`` -> ``numpy.random.default_rng`` under
    ``import numpy as np``. A bare local name maps through a from-import
    (``from time import time`` makes ``time()`` -> ``time.time``).
    """
    dn = dotted_name(func)
    if dn is None:
        return None
    head, _, rest = dn.partition(".")
    canon = mod.import_aliases.get(head)
    if canon is None:
        return dn
    return f"{canon}.{rest}" if rest else canon


def enclosing_context(tree: ast.Module) -> Dict[int, str]:
    """Map every node id to its enclosing def/class qualname."""
    ctx: Dict[int, str] = {}

    def visit(node: ast.AST, qual: str):
        for child in ast.iter_child_nodes(node):
            q = qual
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                q = f"{qual}.{child.name}" if qual != "<module>" \
                    else child.name
            ctx[id(child)] = qual
            visit(child, q)
    ctx[id(tree)] = "<module>"
    visit(tree, "<module>")
    return ctx


def context_of(mod: SourceModule, node: ast.AST) -> str:
    table = getattr(mod, "_ctx_table", None)
    if table is None:
        table = enclosing_context(mod.tree)
        mod._ctx_table = table
    return table.get(id(node), "<module>")


# --------------------------------------------------------------------------
# rule registry
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Rule:
    """A registered check. ``check(modules, config)`` sees the full module
    set so cross-file rules (MASK dispatcher coverage, ACC symmetry) can
    correlate; per-file rules just loop."""
    id: str
    name: str
    doc: str
    check: Callable


RULES: Dict[str, Rule] = {}


def register(rule_id: str, name: str, doc: str):
    def deco(fn):
        if rule_id in RULES:
            raise ValueError(f"duplicate rule id {rule_id}")
        RULES[rule_id] = Rule(id=rule_id, name=name, doc=doc, check=fn)
        return fn
    return deco


def all_rule_ids() -> set:
    _ensure_rules_loaded()
    return set(RULES) | {"LNT001", "LNT002"}


def _ensure_rules_loaded():
    # rule modules self-register on import; idempotent
    from repro.analysis import rules_acc    # noqa: F401
    from repro.analysis import rules_det    # noqa: F401
    from repro.analysis import rules_jax    # noqa: F401
    from repro.analysis import rules_mask   # noqa: F401
    from repro.analysis import rules_pallas  # noqa: F401


def run_rules(modules: Sequence[SourceModule], config
              ) -> Tuple[List[Finding], List[Finding], List[Pragma]]:
    """Run every registered rule, then apply pragma suppression.

    Returns ``(active, suppressed, pragmas)``. Active findings include
    LNT001 (malformed pragma) and LNT002 (pragma that suppressed
    nothing) hygiene findings.
    """
    _ensure_rules_loaded()
    raw: List[Finding] = []
    for rule in sorted(RULES.values(), key=lambda r: r.id):
        raw.extend(rule.check(modules, config))

    by_file: Dict[str, List[Pragma]] = {}
    for mod in modules:
        by_file[mod.relpath] = mod.pragmas

    active: List[Finding] = []
    suppressed: List[Finding] = []
    for f in raw:
        hit = None
        for p in by_file.get(f.path, ()):
            if p.line == f.line and p.rule == f.rule:
                hit = p
                break
        if hit is not None:
            hit.used = True
            suppressed.append(f)
        else:
            active.append(f)

    for mod in modules:
        for line, problem in mod.malformed_pragmas:
            active.append(mod.finding(
                "LNT001", "malformed-pragma", line,
                f"{problem} — use `# lint: disable=RULE(reason)`"))
        for p in mod.pragmas:
            if not p.used:
                active.append(mod.finding(
                    "LNT002", "unused-pragma", p.line,
                    f"pragma disables {p.rule} but nothing on this line "
                    f"triggers it; delete the stale suppression"))

    # LNT findings are themselves suppressible (rarely needed, but keeps
    # the mechanism uniform)
    final_active: List[Finding] = []
    for f in active:
        if f.rule.startswith("LNT"):
            hit = None
            for p in by_file.get(f.path, ()):
                if p.line == f.line and p.rule == f.rule:
                    hit = p
                    break
            if hit is not None:
                hit.used = True
                suppressed.append(f)
                continue
        final_active.append(f)

    order = lambda f: (f.path, f.line, f.rule)
    final_active.sort(key=order)
    suppressed.sort(key=order)
    all_pragmas = [p for mod in modules for p in mod.pragmas]
    return final_active, suppressed, all_pragmas

"""Zero-drift baseline: the committed ledger of tolerated findings.

The baseline maps finding fingerprints (line-number-free; see
``Finding.fingerprint``) to counts. ``--check`` fails on EITHER
direction of drift:

  * a finding not covered by the baseline (new violation), or
  * a baseline entry with no matching finding (the violation was fixed
    but the shrink was not committed — a stale baseline would mask the
    next regression at the same fingerprint).

The repo lands with an EMPTY baseline: every real finding is either
fixed or pragma-suppressed with a reason at the line. The baseline
exists for ratcheting future rules in over a dirty codebase, not as a
dumping ground.
"""
from __future__ import annotations

import collections
import json
import os
from typing import Dict, List, Sequence, Tuple

from repro.analysis.core import Finding

VERSION = 1


def count_findings(findings: Sequence[Finding]) -> Dict[str, int]:
    counts: Dict[str, int] = collections.Counter()
    for f in findings:
        counts[f.fingerprint] += 1
    return dict(counts)


def load_baseline(path: str) -> Dict[str, int]:
    if not os.path.exists(path):
        return {}
    with open(path, "r", encoding="utf-8") as fh:
        data = json.load(fh)
    if data.get("version") != VERSION:
        raise ValueError(
            f"baseline {path} has version {data.get('version')!r}; "
            f"this linter writes version {VERSION} — regenerate with "
            f"--update-baseline")
    findings = data.get("findings", {})
    if not isinstance(findings, dict):
        raise ValueError(f"baseline {path}: 'findings' must be a mapping")
    return {str(k): int(v) for k, v in findings.items()}


def save_baseline(path: str, findings: Sequence[Finding]) -> None:
    payload = {
        "version": VERSION,
        "findings": dict(sorted(count_findings(findings).items())),
    }
    tmp = path + ".tmp"
    with open(tmp, "w", encoding="utf-8") as fh:
        json.dump(payload, fh, indent=1, sort_keys=True)
        fh.write("\n")
    os.replace(tmp, path)


def diff_baseline(findings: Sequence[Finding], baseline: Dict[str, int]
                  ) -> Tuple[List[Finding], List[str]]:
    """Returns ``(new, stale)``: findings beyond their baselined count,
    and baseline fingerprints whose counted findings shrank."""
    current = count_findings(findings)
    budget = dict(baseline)
    new: List[Finding] = []
    for f in findings:
        if budget.get(f.fingerprint, 0) > 0:
            budget[f.fingerprint] -= 1
        else:
            new.append(f)
    stale = sorted(fp for fp, n in budget.items()
                   if n > 0 and current.get(fp, 0) < baseline[fp])
    return new, stale

"""Repo-native static analysis (DESIGN.md §13).

The replay/quality trajectory (BENCH_HISTORY.json, the scheduler-quality
CI gate) is only trustworthy while scheduling decisions stay a pure
function of recorded inputs, donated buffers are never read back, and
every masked entrypoint honors the lane-mask contract. Those invariants
are cross-layer and easy to break silently; this package checks them
per-PR with AST rules instead of hoping a runtime test hits the bad path.

Entry point: ``python -m repro.analysis.lint`` (``--check`` is the CI
gate). Rule families: DET (determinism on the decision path), JAX
(donation / retrace hazards), MASK (lane-mask contract), ACC (monitor
counter symmetry). See DESIGN.md §13 for the catalog and the
suppression / baseline workflow.
"""
from repro.analysis.config import LintConfig, default_config
from repro.analysis.core import Finding, SourceModule, run_rules
from repro.analysis.driver import LintResult, run_lint

__all__ = [
    "Finding",
    "LintConfig",
    "LintResult",
    "SourceModule",
    "default_config",
    "run_lint",
    "run_rules",
]

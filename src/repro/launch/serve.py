"""Serving driver: prefill + continuously-batched decode on a lane pool.

The serve_step builders are what the dry-run lowers for decode shapes; the
``BatchServer`` is a runnable mini-server for the examples. It is TRUE
continuous batching (the inference-side analogue of the paper's
concurrent-jobs-per-GPU packing, on the persistent-lane-pool model of
core/lanepool.py):

  * the decode state is a fixed-capacity pool — per-lane KV caches stacked
    on a leading lane axis, decode compiled ONCE as a vmap over lanes;
  * a request joins MID-DECODE the moment a lane frees: its prompt is
    prefilled at batch 1 and its cache swapped into the free lane via a
    pytree index update (no recompilation, other lanes undisturbed);
  * a finished lane stops burning decode budget — its request is retired
    immediately (``Request.done``) and the next queued request takes the
    lane, so total active lane-steps equal the sum of per-request
    ``max_new``, not ``capacity × max(max_new)`` (the wave-mode waste).

Lanes are independent under vmap, so a request's tokens are identical
whatever co-residents it decodes next to (prompts are left-padded to one
fixed length per ``run``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing
from repro.models.model import Model


def make_prefill(model: Model, max_len: int) -> Callable:
    def prefill(params, batch):
        return model.prefill(params, batch, max_len=max_len)
    return prefill


def make_serve_step(model: Model) -> Callable:
    """(params, batch{tokens,pos[,mrope_pos]}, cache) -> (logits, cache)."""
    def serve_step(params, batch, cache):
        return model.decode_step(params, batch, cache)
    return serve_step


@dataclasses.dataclass
class Request:
    id: int
    prompt: np.ndarray            # (S,) int32
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class ServeStats:
    """Decode accounting for the last ``BatchServer.run``."""
    global_steps: int = 0         # vmapped decode invocations
    lane_steps: int = 0           # tokens produced (invariant: Σ max_new)
    lane_slots: int = 0           # lane-slots stepped (Σ pool width/step —
                                  # what adaptive resizing shrinks)
    prefills: int = 0
    n_requests: int = 0
    resizes: int = 0              # adaptive lane-pool rebuilds
    lane_trace: List[Tuple[int, int]] = dataclasses.field(
        default_factory=list)     # (global_step, lane count) per resize

    @property
    def occupancy(self) -> float:
        if not self.global_steps:
            return 0.0
        return self.lane_steps / self.global_steps

    @property
    def step_efficiency(self) -> float:
        """Fraction of stepped lane-slots that produced a kept token."""
        if not self.lane_slots:
            return 0.0
        return self.lane_steps / self.lane_slots


class BatchServer:
    """Greedy-decode server over a persistent lane pool.

    With ``adaptive_lanes`` the pool RESIZES to queue depth between decode
    steps (the serving face of online elastic repacking, core/repack.py):
    as the request tail drains, live lanes are compacted into a smaller
    pool so the vmapped step stops paying for dead lanes. Lane counts are
    rounded to powers of two, so at most log2(batch_lanes) decode variants
    ever compile; per-request tokens are unchanged (lanes are independent
    under vmap).
    """

    def __init__(self, model: Model, params, batch_lanes: int, max_len: int,
                 adaptive_lanes: bool = False):
        self.model = model
        self.params = params
        self.lanes = batch_lanes
        self.max_len = max_len
        self.adaptive_lanes = adaptive_lanes
        self.stats = ServeStats()
        self._prefill = jax.jit(make_prefill(model, max_len))
        # decode one lane at batch 1, vmapped over the lane axis of the
        # cache pool — compiled once per run() shape set
        self._step = jax.jit(jax.vmap(make_serve_step(model),
                                      in_axes=(None, 0, 0)),
                             donate_argnums=(2,))

    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        queue = [r for r in list(requests) if r.max_new > 0]
        for r in requests:
            if r.max_new <= 0:
                r.done = True
        results: Dict[int, List[int]] = {r.id: r.out for r in requests}
        self.stats = ServeStats(n_requests=len(queue))
        if not queue:
            return results
        S_pad = max(len(r.prompt) for r in queue)
        # enqueue-time KV guard: decode writes positions S_pad .. S_pad +
        # max_new - 2 (the first token comes from prefill), so the cache
        # must hold S_pad + max_new - 1 positions. Reject up front instead
        # of silently walking ``pos`` past the cache length.
        for r in queue:
            if S_pad + r.max_new - 1 > self.max_len:
                raise ValueError(
                    f"request {r.id}: padded prompt ({S_pad}) + max_new "
                    f"({r.max_new}) needs {S_pad + r.max_new - 1} KV "
                    f"positions > max_len ({self.max_len}); shorten the "
                    f"prompt or raise max_len")
        C = min(self.lanes, len(queue))

        def prefill_one(r: Request):
            toks = np.zeros((1, S_pad), np.int32)
            toks[0, S_pad - len(r.prompt):] = r.prompt   # left-pad
            logits, cache = self._prefill(self.params,
                                          {"tokens": jnp.asarray(toks)})
            self.stats.prefills += 1
            first = jnp.argmax(logits, -1).astype(jnp.int32)   # (1,)
            return first, cache

        # seed the pool from the first prefill so every leaf has its lane
        # axis before any swap (shapes fixed until an adaptive resize)
        first0, cache0 = prefill_one(queue[0])
        pool_cache = packing.stack_trees([cache0] * C)
        cur = np.zeros((C, 1, 1), np.int32)          # per-lane (B=1, T=1)
        pos = np.full((C, 1), S_pad, np.int32)
        lane_req: List[Optional[Request]] = [None] * C

        def attach(lane: int, r: Request, first=None, cache=None):
            nonlocal pool_cache
            if first is None:
                first, cache = prefill_one(r)
            pool_cache = packing.tree_set_lane(pool_cache, lane, cache)
            cur[lane, 0, 0] = int(first[0])
            pos[lane, 0] = S_pad
            lane_req[lane] = r

        def resize(new_c: int):
            """Compact live lanes into a pool of ``new_c`` lanes (pure
            pytree reads/stack — per-lane state is untouched)."""
            nonlocal pool_cache, cur, pos, lane_req, C
            live = [l for l, r in enumerate(lane_req) if r is not None]
            caches = [packing.tree_get_lane(pool_cache, l) for l in live]
            template = caches[0] if caches \
                else packing.tree_get_lane(pool_cache, 0)
            new_cache = packing.stack_trees(
                caches + [template] * (new_c - len(caches)))
            new_cur = np.zeros((new_c, 1, 1), np.int32)
            new_pos = np.full((new_c, 1), S_pad, np.int32)
            new_req: List[Optional[Request]] = [None] * new_c
            for i, l in enumerate(live):
                new_cur[i] = cur[l]
                new_pos[i] = pos[l]
                new_req[i] = lane_req[l]
            pool_cache, cur, pos, lane_req, C = \
                new_cache, new_cur, new_pos, new_req, new_c
            self.stats.resizes += 1
            self.stats.lane_trace.append((self.stats.global_steps, new_c))

        attach(0, queue.pop(0), first0, cache0)
        for lane in range(1, C):
            if queue:
                attach(lane, queue.pop(0))

        while True:
            # emit + retire phase: the token each active lane carries came
            # from the PREVIOUS step (or its prefill). Record it, and
            # retire lanes whose budget is now exhausted BEFORE stepping —
            # stepping a finished lane would produce a token nobody
            # consumes (one wasted vmapped step per request).
            for lane, r in enumerate(lane_req):
                if r is None:
                    continue
                r.out.append(int(cur[lane, 0, 0]))
                self.stats.lane_steps += 1
                if len(r.out) >= r.max_new:
                    r.done = True        # lane frees NOW — no wave barrier
                    lane_req[lane] = None
            n_live = sum(1 for r in lane_req if r is not None)
            if n_live == 0 and not queue:
                break
            if self.adaptive_lanes:
                demand = n_live + len(queue)
                desired = 1 << (max(1, demand) - 1).bit_length()
                desired = min(self.lanes, max(desired, n_live, 1))
                if desired < C:
                    resize(desired)
            if n_live:
                active = np.array([r is not None for r in lane_req])
                logits, pool_cache = self._step(
                    self.params,
                    {"tokens": jnp.asarray(cur), "pos": jnp.asarray(pos)},
                    pool_cache)
                nxt = np.asarray(jnp.argmax(logits, -1), np.int32)  # (C, 1)
                self.stats.global_steps += 1
                self.stats.lane_slots += C
                cur[active, 0, 0] = nxt[active, 0]
                pos[active, 0] += 1      # inactive lanes stay frozen
            # refill phase — strictly AFTER the step: a joiner's first
            # token (from its prefill) sits in ``cur`` and must be
            # emitted next iteration before the lane is ever stepped;
            # attaching pre-step would let the step consume and overwrite
            # it, shifting the request's whole output by one
            for lane, r in enumerate(lane_req):
                if r is None and queue:  # waiting request joins mid-decode
                    attach(lane, queue.pop(0))
        return results

"""Serving driver: prefill + continuously-batched decode on a lane pool.

The serve_step builders are what the dry-run lowers for decode shapes; the
``BatchServer`` is a runnable mini-server for the examples. It is TRUE
continuous batching (the inference-side analogue of the paper's
concurrent-jobs-per-GPU packing, on the persistent-lane-pool model of
core/lanepool.py):

  * the decode state is a fixed-capacity pool — per-lane KV caches stacked
    on a leading lane axis, decode compiled ONCE as a vmap over lanes;
  * a request joins MID-DECODE the moment a lane frees: its prompt is
    prefilled at batch 1 and its cache swapped into the free lane via a
    pytree index update (no recompilation, other lanes undisturbed);
  * a finished lane stops burning decode budget — its request is retired
    immediately (``Request.done``) and the next queued request takes the
    lane, so total active lane-steps equal the sum of per-request
    ``max_new``, not ``capacity × max(max_new)`` (the wave-mode waste).

Lanes are independent under vmap, so a request's tokens are identical
whatever co-residents it decodes next to (prompts are left-padded to one
fixed length per ``run``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing
from repro.models.model import Model


def make_prefill(model: Model, max_len: int) -> Callable:
    def prefill(params, batch):
        return model.prefill(params, batch, max_len=max_len)
    return prefill


def make_serve_step(model: Model) -> Callable:
    """(params, batch{tokens,pos[,mrope_pos]}, cache) -> (logits, cache)."""
    def serve_step(params, batch, cache):
        return model.decode_step(params, batch, cache)
    return serve_step


@dataclasses.dataclass
class Request:
    id: int
    prompt: np.ndarray            # (S,) int32
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class ServeStats:
    """Decode accounting for the last ``BatchServer.run``."""
    global_steps: int = 0         # vmapped decode invocations
    lane_steps: int = 0           # active lane-steps (tokens produced)
    prefills: int = 0
    n_requests: int = 0

    @property
    def occupancy(self) -> float:
        if not self.global_steps:
            return 0.0
        return self.lane_steps / self.global_steps


class BatchServer:
    """Greedy-decode server over a persistent lane pool."""

    def __init__(self, model: Model, params, batch_lanes: int, max_len: int):
        self.model = model
        self.params = params
        self.lanes = batch_lanes
        self.max_len = max_len
        self.stats = ServeStats()
        self._prefill = jax.jit(make_prefill(model, max_len))
        # decode one lane at batch 1, vmapped over the lane axis of the
        # cache pool — compiled once per run() shape set
        self._step = jax.jit(jax.vmap(make_serve_step(model),
                                      in_axes=(None, 0, 0)),
                             donate_argnums=(2,))

    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        queue = [r for r in list(requests) if r.max_new > 0]
        for r in requests:
            if r.max_new <= 0:
                r.done = True
        results: Dict[int, List[int]] = {r.id: r.out for r in requests}
        self.stats = ServeStats(n_requests=len(queue))
        if not queue:
            return results
        C = min(self.lanes, len(queue))
        S_pad = max(len(r.prompt) for r in queue)

        def prefill_one(r: Request):
            toks = np.zeros((1, S_pad), np.int32)
            toks[0, S_pad - len(r.prompt):] = r.prompt   # left-pad
            logits, cache = self._prefill(self.params,
                                          {"tokens": jnp.asarray(toks)})
            self.stats.prefills += 1
            first = jnp.argmax(logits, -1).astype(jnp.int32)   # (1,)
            return first, cache

        # seed the pool from the first prefill so every leaf has its lane
        # axis before any swap (shapes fixed for the whole run)
        first0, cache0 = prefill_one(queue[0])
        pool_cache = packing.stack_trees([cache0] * C)
        cur = np.zeros((C, 1, 1), np.int32)          # per-lane (B=1, T=1)
        pos = np.full((C, 1), S_pad, np.int32)
        lane_req: List[Optional[Request]] = [None] * C

        def attach(lane: int, r: Request, first=None, cache=None):
            nonlocal pool_cache
            if first is None:
                first, cache = prefill_one(r)
            pool_cache = packing.tree_set_lane(pool_cache, lane, cache)
            cur[lane, 0, 0] = int(first[0])
            pos[lane, 0] = S_pad
            lane_req[lane] = r

        attach(0, queue.pop(0), first0, cache0)
        for lane in range(1, C):
            if queue:
                attach(lane, queue.pop(0))

        while any(r is not None for r in lane_req):
            active = np.array([r is not None for r in lane_req])
            # record the token each active lane is about to consume/emit
            for lane, r in enumerate(lane_req):
                if r is not None:
                    r.out.append(int(cur[lane, 0, 0]))
            logits, pool_cache = self._step(
                self.params,
                {"tokens": jnp.asarray(cur), "pos": jnp.asarray(pos)},
                pool_cache)
            nxt = np.asarray(jnp.argmax(logits, -1), np.int32)   # (C, 1)
            self.stats.global_steps += 1
            self.stats.lane_steps += int(active.sum())
            cur[active, 0, 0] = nxt[active, 0]
            pos[active, 0] += 1          # inactive lanes stay frozen
            for lane, r in enumerate(lane_req):
                if r is None:
                    continue
                if len(r.out) >= r.max_new:
                    r.done = True        # lane frees NOW — no wave barrier
                    lane_req[lane] = None
                    if queue:            # a waiting request joins mid-decode
                        attach(lane, queue.pop(0))
        return results

"""Serving driver: prefill + batched decode (continuous-batching-lite).

The serve_step builders are what the dry-run lowers for decode shapes; the
``BatchServer`` is a runnable mini-server for the examples: fixed-size lane
pool, new requests join as lanes free up (the inference-side analogue of
the paper's concurrent-jobs-per-GPU packing).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


def make_prefill(model: Model, max_len: int) -> Callable:
    def prefill(params, batch):
        return model.prefill(params, batch, max_len=max_len)
    return prefill


def make_serve_step(model: Model) -> Callable:
    """(params, batch{tokens,pos[,mrope_pos]}, cache) -> (logits, cache)."""
    def serve_step(params, batch, cache):
        return model.decode_step(params, batch, cache)
    return serve_step


@dataclasses.dataclass
class Request:
    id: int
    prompt: np.ndarray            # (S,) int32
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class BatchServer:
    """Greedy-decode server over a fixed lane pool."""

    def __init__(self, model: Model, params, batch_lanes: int, max_len: int):
        self.model = model
        self.params = params
        self.lanes = batch_lanes
        self.max_len = max_len
        self._prefill = jax.jit(make_prefill(model, max_len))
        self._step = jax.jit(make_serve_step(model), donate_argnums=(2,))

    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        queue = list(requests)
        results: Dict[int, List[int]] = {}
        while queue:
            active = queue[:self.lanes]
            queue = queue[self.lanes:]
            B = len(active)
            S = max(len(r.prompt) for r in active)
            toks = np.zeros((B, S), np.int32)
            for i, r in enumerate(active):
                toks[i, -len(r.prompt):] = r.prompt  # left-pad
            logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(toks)})
            cur = jnp.argmax(logits, -1).astype(jnp.int32)
            pos = jnp.full((B,), S, jnp.int32)
            max_new = max(r.max_new for r in active)
            outs = [[] for _ in active]
            for t in range(max_new):
                for i in range(B):
                    outs[i].append(int(cur[i]))
                logits, cache = self._step(
                    self.params, {"tokens": cur[:, None], "pos": pos}, cache)
                cur = jnp.argmax(logits, -1).astype(jnp.int32)
                pos = pos + 1
            for r, o in zip(active, outs):
                results[r.id] = o[:r.max_new]
        return results

"""Parametric-study sweep driver — the paper's headline use case.

Runs K training tasks (same architecture, different hyperparameters / data
seeds) under a triples placement: auto_nppn picks the largest safe packing
factor, tasks run as lanes of a persistent lane pool (core/lanepool.py)
with CONTINUOUS REFILL — the moment a lane's task exhausts its per-task
step budget (``SweepTask.steps``) or early-stops, the next queued task
attaches in its place, between two masked steps. The pool is compiled once
over the packing factor; no wave boundary, no recompilation, no idle lanes
while work remains queued.

Checkpoints are per task (``{checkpoint_dir}/task_{id}``), written when a
lane detaches and every ``FaultPolicy.checkpoint_every`` steps mid-flight;
a re-run restores each task's saved state and skips the finished steps. OOM-backoff halves the pool capacity and re-enqueues the
unfinished tasks (in-flight progress of the failed pool is discarded, as a
packed-program OOM kills all lanes at once).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.checkpoint import Checkpointer
from repro.core import autotune, packing
from repro.core import repack as rp
from repro.core.faults import FaultPolicy
from repro.core.lanepool import (LanePool, LaneTask, PoolStepError,
                                 RefillExecutor, RefillStats)
from repro.core.monitor import RunMonitor, TenantGauges
from repro.core.tenancy import MemoryAdmission
from repro.launch.train import make_train_step
from repro.models.model import Model


@dataclasses.dataclass
class SweepTask:
    id: int
    lr: float
    seed: int
    steps: Optional[int] = None         # per-task budget (None = sweep-wide)


@dataclasses.dataclass
class SweepResult:
    losses: Dict[int, List[float]]
    wall_s: float
    pack_factor: int
    backoffs: int = 0
    bytes_per_lane: int = 0             # admission footprint (0 = unprobed)
    admission_capped: bool = False      # pack shrunk by MemoryAdmission
    global_steps: int = 0               # masked pool steps executed
    lane_steps: int = 0                 # active lane-steps (useful work)
    refills: int = 0                    # lane attaches performed
    n_traces: int = 0                   # jit traces of the packed step
    preempted: bool = False             # drained to checkpoints mid-run;
                                        # re-run with the same
                                        # checkpoint_dir resumes (at any
                                        # max_pack) bit-identically
    repacks: int = 0                    # adaptive_pack capacity changes
    capacity_trace: List[tuple] = dataclasses.field(
        default_factory=list)           # (global_step, new_capacity)


def run_sweep(model: Model, tasks: Sequence[SweepTask], *,
              batch_fn: Callable[[int, int], Any],   # (seed, step) -> batch
              steps: int,
              hbm_budget: Optional[float] = None,
              max_pack: Optional[int] = None,
              checkpoint_dir: Optional[str] = None,
              policy: Optional[FaultPolicy] = None,
              opt: Optional[optim.Optimizer] = None,
              admission: Optional[MemoryAdmission] = None,
              tenant: str = "default",
              gauges: Optional[TenantGauges] = None,
              early_stop: Optional[Callable[[SweepTask, int, float], bool]]
              = None,
              preempt: Optional[Callable[[RefillStats], bool]]
              = None,
              stragglers_fn: Optional[Callable[[], List[int]]] = None,
              adaptive_pack: bool = False,
              repack_policy: Optional[rp.RepackPolicy] = None,
              measure_bytes: Optional[Callable[[], float]] = None
              ) -> SweepResult:
    """Train all tasks on a continuously-refilled lane pool.

    ``steps`` is the sweep-wide budget; a task's own ``SweepTask.steps``
    overrides it (skewed-duration sweeps). ``early_stop(task, step, loss)``
    may retire a lane early — its slot refills immediately. With
    ``admission`` set, the per-lane footprint of the compiled single-lane
    step caps the pool capacity BEFORE anything runs (multi-tenant
    admission control, DESIGN.md §4.3); ``gauges`` charges the pool to
    ``tenant`` in the shared per-tenant LLload table and receives per-step
    lane-occupancy samples for the ``sweep:{tenant}`` gang.

    Preemption (DESIGN.md §8): ``preempt(stats)`` is consulted after
    every pool step; when it fires the pool DRAINS — every in-flight
    lane's state is checkpointed at its exact cursor — and the call
    returns with ``SweepResult.preempted`` set. A later ``run_sweep``
    with the same ``checkpoint_dir`` (and ANY ``max_pack``, e.g. half
    when only partial capacity freed) resumes every task from its saved
    step and produces bit-identical remaining losses: lanes are
    independent under vmap and batches are keyed (seed, step), so the
    loss stream cannot depend on which lane or capacity served it.
    Requires ``checkpoint_dir`` — a drain without a checkpoint seam
    would silently discard progress.

    Speculative stragglers (``FaultPolicy.speculative_stragglers``):
    flagged lanes duplicate onto free pool slots, first result wins.
    On THIS substrate's single-host lockstep pool every lane steps in
    one compiled call, so per-lane step-time skew cannot arise and the
    default monitor signal never flags anyone — pass ``stragglers_fn``
    to supply a real signal (per-device pools, external telemetry, or
    tests); the default stays ``RunMonitor.stragglers`` (EWMA per-lane
    times, live once lane times exist).

    Online elastic repacking (``adaptive_pack`` — DESIGN.md §9): skip
    the static auto_nppn probe entirely, start at the conservative
    ``RepackPolicy.start_capacity`` and let a RepackController converge
    the pack factor to the frontier ONLINE from live telemetry
    (occupancy EWMA, queue depth, measured pool footprint vs
    ``hbm_budget``). Per-task losses stay bit-identical across repacks;
    ``SweepResult.repacks``/``capacity_trace`` record the trajectory
    and the final ``pack_factor`` is the converged capacity. When
    ``admission`` is set, each repack reports the MEASURED per-lane
    footprint to it (record_measured), so later scheduler admissions
    for this tenant consume measurements instead of static profiles.
    ``measure_bytes`` injects a footprint telemetry source (default:
    live jax array accounting)."""
    policy = policy or FaultPolicy()
    if preempt is not None and not checkpoint_dir:
        raise ValueError("preempt requires checkpoint_dir: draining "
                         "without a checkpoint seam discards progress")
    opt = opt or optim.adamw(weight_decay=0.0)
    step_fn = make_train_step(model, opt)

    # ---- choose packing factor (auto_nppn) ----
    n = len(tasks)
    if max_pack is None:
        max_pack = n

    def make_packed(k):
        return jax.vmap(step_fn)

    def example_args(k):
        keys = jax.random.split(jax.random.PRNGKey(0), k)
        p = jax.vmap(model.init)(keys)
        o = jax.vmap(opt.init)(p)
        b = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (k, *x.shape)),
            jax.tree_util.tree_map(jnp.asarray, batch_fn(0, 0)))
        lr = jnp.zeros((k,), jnp.float32)
        return (p, o, b, lr)

    single_profile = None
    repack_pol = repack_policy or rp.RepackPolicy()
    if adaptive_pack:
        # conservative start; the controller converges online (no probe)
        pack = max(1, min(repack_pol.start_capacity, max_pack, n))
    elif hbm_budget is not None:
        decision = autotune.auto_nppn(make_packed, example_args,
                                      hbm_budget, max_factor=max_pack)
        pack = decision.nppn_per_chip
        single_profile = decision.profile_single
    else:
        pack = min(max_pack, n)

    # ---- memory-aware admission: footprint caps the pool up front ----
    bytes_per_lane = 0
    admission_capped = False
    if admission is not None:
        if single_profile is None:      # auto_nppn already probed k=1
            compiled = jax.jit(make_packed(1)).lower(*example_args(1)).compile()
            bytes_per_lane = packing.memory_per_lane(compiled)
        else:
            bytes_per_lane = single_profile.resident_bytes
        try:
            cap = admission.require_fits(bytes_per_lane)
        except MemoryError as e:
            raise MemoryError(f"tenant {tenant!r}: {e}") from None
        if pack > cap:
            pack, admission_capped = cap, True

    # ---- continuous refill over a persistent lane pool ----
    t0 = time.perf_counter()
    losses: Dict[int, List[float]] = {t.id: [] for t in tasks}
    mon = RunMonitor(straggler_ratio=policy.straggler_ratio)
    backoffs = 0
    preempted = False
    totals = dict(global_steps=0, lane_steps=0, refills=0, n_traces=0,
                  repacks=0)
    capacity_trace: List[tuple] = []
    gang = f"sweep:{tenant}"
    adaptive_pol = None
    if adaptive_pack:
        adaptive_pol = repack_pol
        if admission is not None and bytes_per_lane > 0:
            # admission's static cap bounds online growth too (the
            # measured frontier may later shrink it further)
            adaptive_pol = dataclasses.replace(
                adaptive_pol,
                max_capacity=max(adaptive_pol.min_capacity,
                                 min(adaptive_pol.max_capacity,
                                     admission.require_fits(bytes_per_lane))))

    # ONE Checkpointer per task for the whole sweep: its save(blocking=
    # False) joins the previous thread, so async saves to a task dir
    # serialize and restore can never race a garbage collection
    _cks: Dict[int, Checkpointer] = {}
    _restored_done: set = set()         # finished in a PREVIOUS run: skip,
                                        # and do not re-save their artifact

    def ck_for(task_id: int) -> Checkpointer:
        if task_id not in _cks:
            _cks[task_id] = Checkpointer(f"{checkpoint_dir}/task_{task_id}")
        return _cks[task_id]

    def make_lane_task(t: SweepTask) -> LaneTask:
        budget = steps if t.steps is None else t.steps
        lt = LaneTask(id=t.id, hparams=jnp.float32(t.lr), init_fn=None,
                      batch_fn=lambda s, seed=t.seed: batch_fn(seed, s),
                      steps=budget)

        def init_fn(lt=lt, t=t):
            params = model.init(jax.random.PRNGKey(t.seed))
            opt_state = opt.init(params)
            lt.step_done = 0
            if checkpoint_dir:
                try:
                    (params, opt_state), start, extra = ck_for(
                        t.id).restore((params, opt_state))
                    lt.step_done = start
                    if extra.get("done"):   # finished or early-stopped in
                        lt.step_done = lt.steps     # a previous run: skip
                        _restored_done.add(t.id)
                except FileNotFoundError:
                    pass
            # keep the recorded history consistent with the attach point
            # (covers both OOM-backoff re-attach — resume from the last
            # mid-flight save, dropping unsaved steps — and fresh restart)
            losses[t.id] = losses[t.id][:lt.step_done]
            return params, opt_state

        lt.init_fn = init_fn
        return lt

    by_id = {t.id: t for t in tasks}
    queue = [make_lane_task(t) for t in tasks]
    template = model.init(jax.random.PRNGKey(0))
    while queue:
        pool = LanePool(min(pack, len(queue)), step_fn,
                        template_params=template,
                        template_opt=opt.init(template),
                        template_hparams=jnp.float32(0.0))
        if gauges is not None:
            gauges.on_dispatch(tenant, nodes=1, lanes=pool.capacity,
                               resident_bytes=bytes_per_lane * pool.capacity)
        t_pool = time.perf_counter()
        finished: set = set()

        def on_metrics(lt: LaneTask, step_idx: int, lane_metrics) -> bool:
            losses[lt.id].append(float(np.asarray(lane_metrics["loss"])))
            if early_stop is not None:
                return bool(early_stop(by_id[lt.id], step_idx,
                                       losses[lt.id][-1]))
            return False

        def on_finish(lt: LaneTask, params, opt_state):
            finished.add(lt.id)
            if checkpoint_dir and lt.id not in _restored_done:
                ck = ck_for(lt.id)      # async path joins the pending
                ck.save((params, opt_state), lt.step_done,
                        extra={"done": True}, blocking=False)
                ck.wait()               # mid-flight save before this one

        def on_checkpoint(lt: LaneTask, params, opt_state):
            ck_for(lt.id).save((params, opt_state), lt.step_done,
                               blocking=False)

        def on_preempt(lt: LaneTask, params, opt_state):
            # drain: the lane's exact cursor goes to the task's own
            # checkpoint dir — the resume path is the ordinary restore
            ck = ck_for(lt.id)
            ck.save((params, opt_state), lt.step_done, blocking=False)
            ck.wait()

        def on_step(global_step: int, active: int, capacity: int):
            mon.end_step(global_step)
            if gauges is not None:
                gauges.on_lane_sample(tenant, gang, active, capacity)

        # one controller PER POOL ATTEMPT: an OOM-backoff retry gets a
        # fresh cooldown anchor and repack budget (a private gauge set —
        # the sweep's own on_step already samples the shared ``gauges``
        # for this gang; sharing them here would double-decay the EWMA)
        controller = None
        if adaptive_pol is not None:
            controller = rp.RepackController(
                adaptive_pol, hbm_budget=hbm_budget, tenant=tenant,
                gang=f"repack:{gang}", admission=admission,
                measure_bytes=measure_bytes)

        ex = RefillExecutor(
            pool, on_metrics=on_metrics, on_finish=on_finish,
            on_step_start=mon.start_step, on_step=on_step,
            checkpoint_every=(policy.checkpoint_every
                              if checkpoint_dir else 0),
            on_checkpoint=on_checkpoint if checkpoint_dir else None,
            should_preempt=preempt,
            on_preempt=on_preempt if checkpoint_dir else None,
            speculative=policy.speculative_stragglers,
            stragglers_fn=stragglers_fn or mon.stragglers,
            repack_policy=controller)
        try:
            stats = ex.run(queue)
        except PoolStepError:   # pool-wide OOM: halve capacity, redo
                                # unfinished (callback bugs propagate raw)
            if policy.oom_backoff and ex.pool.capacity > policy.min_pack_factor:
                backoffs += 1
                # halve from where the pool actually WAS (adaptive repack
                # may have moved it since dispatch)
                pack = max(policy.min_pack_factor, ex.pool.capacity // 2)
                totals["n_traces"] += ex.n_traces
                if adaptive_pol is not None:
                    # the retry's fresh controller must not regrow past
                    # the capacity that just OOM'd, or the halve/regrow
                    # cycle never terminates — each backoff lowers the
                    # ceiling, preserving the static path's log2 bound
                    adaptive_pol = dataclasses.replace(
                        adaptive_pol,
                        max_capacity=max(adaptive_pol.min_capacity,
                                         min(adaptive_pol.max_capacity,
                                             pack)))
                # unfinished tasks re-attach via init_fn, which resumes
                # from their last saved checkpoint (or step 0) and trims
                # the loss history to match — the failed pool's unsaved
                # progress is lost, as a packed OOM kills all lanes
                queue = [lt for lt in queue if lt.id not in finished]
                if gauges is not None:
                    gauges.on_release(
                        tenant, nodes=1,
                        node_time=time.perf_counter() - t_pool,
                        lanes=pool.capacity,
                        resident_bytes=bytes_per_lane * pool.capacity)
                continue
            raise
        totals["global_steps"] += stats.global_steps
        totals["lane_steps"] += stats.lane_steps
        totals["refills"] += stats.attaches
        totals["n_traces"] += stats.n_traces
        totals["repacks"] += stats.repacks
        capacity_trace.extend(stats.capacity_trace)
        if adaptive_pack:
            pack = ex.pool.capacity     # report the CONVERGED factor
        if stats.preempted:
            preempted = True            # drained to per-task checkpoints;
                                        # a re-run resumes every cursor
        if gauges is not None:
            gauges.on_release(tenant, nodes=1,
                              node_time=time.perf_counter() - t_pool,
                              lanes=pool.capacity,
                              resident_bytes=bytes_per_lane * pool.capacity)
        queue = []

    for ck in _cks.values():            # join any pending async saves
        ck.wait()
    return SweepResult(losses=losses, wall_s=time.perf_counter() - t0,
                       pack_factor=pack, backoffs=backoffs,
                       bytes_per_lane=bytes_per_lane,
                       admission_capped=admission_capped,
                       global_steps=totals["global_steps"],
                       lane_steps=totals["lane_steps"],
                       refills=totals["refills"],
                       n_traces=totals["n_traces"],
                       preempted=preempted,
                       repacks=totals["repacks"],
                       capacity_trace=capacity_trace)

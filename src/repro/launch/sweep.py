"""Parametric-study sweep driver — the paper's headline use case.

Runs K training tasks (same architecture, different hyperparameters / data
seeds) under a triples placement: auto_nppn picks the largest safe packing
factor, tasks pack as vmapped lanes, the monitor watches for stragglers,
checkpoints make OOM-backoff / node-loss recovery lossless.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.checkpoint import Checkpointer
from repro.core import autotune, packing, triples as T
from repro.core.faults import FaultPolicy, TaskOOM
from repro.core.monitor import RunMonitor, TenantGauges
from repro.core.tenancy import MemoryAdmission
from repro.launch.train import make_train_step
from repro.models.model import Model


@dataclasses.dataclass
class SweepTask:
    id: int
    lr: float
    seed: int


@dataclasses.dataclass
class SweepResult:
    losses: Dict[int, List[float]]
    wall_s: float
    pack_factor: int
    backoffs: int = 0
    bytes_per_lane: int = 0             # admission footprint (0 = unprobed)
    admission_capped: bool = False      # pack shrunk by MemoryAdmission


def run_sweep(model: Model, tasks: Sequence[SweepTask], *,
              batch_fn: Callable[[int, int], Any],   # (seed, step) -> batch
              steps: int,
              hbm_budget: Optional[float] = None,
              max_pack: Optional[int] = None,
              checkpoint_dir: Optional[str] = None,
              policy: Optional[FaultPolicy] = None,
              opt: Optional[optim.Optimizer] = None,
              admission: Optional[MemoryAdmission] = None,
              tenant: str = "default",
              gauges: Optional[TenantGauges] = None) -> SweepResult:
    """Train all tasks; packing factor chosen by the memory guard.

    With ``admission`` set, the per-lane footprint of the compiled
    single-lane step caps the packing factor BEFORE the first wave runs
    (multi-tenant admission control, DESIGN.md §4.3); ``gauges`` charges
    the waves to ``tenant`` in the shared per-tenant LLload table."""
    policy = policy or FaultPolicy()
    opt = opt or optim.adamw(weight_decay=0.0)
    step_fn = make_train_step(model, opt)

    # ---- choose packing factor (auto_nppn) ----
    n = len(tasks)
    if max_pack is None:
        max_pack = n

    def make_packed(k):
        return jax.vmap(step_fn)

    def example_args(k):
        keys = jax.random.split(jax.random.PRNGKey(0), k)
        p = jax.vmap(model.init)(keys)
        o = jax.vmap(opt.init)(p)
        b = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (k, *x.shape)),
            jax.tree_util.tree_map(jnp.asarray, batch_fn(0, 0)))
        lr = jnp.zeros((k,), jnp.float32)
        return (p, o, b, lr)

    single_profile = None
    if hbm_budget is not None:
        decision = autotune.auto_nppn(make_packed, example_args,
                                      hbm_budget, max_factor=max_pack)
        pack = decision.nppn_per_chip
        single_profile = decision.profile_single
    else:
        pack = min(max_pack, n)

    # ---- memory-aware admission: footprint caps the pack up front ----
    bytes_per_lane = 0
    admission_capped = False
    if admission is not None:
        if single_profile is None:      # auto_nppn already probed k=1
            compiled = jax.jit(make_packed(1)).lower(*example_args(1)).compile()
            bytes_per_lane = packing.memory_per_lane(compiled)
        else:
            bytes_per_lane = single_profile.resident_bytes
        try:
            cap = admission.require_fits(bytes_per_lane)
        except MemoryError as e:
            raise MemoryError(f"tenant {tenant!r}: {e}") from None
        if pack > cap:
            pack, admission_capped = cap, True

    # ---- run waves of `pack` lanes ----
    t0 = time.perf_counter()
    losses: Dict[int, List[float]] = {t.id: [] for t in tasks}
    packed_fn = packing.packed_step(step_fn)
    mon = RunMonitor(straggler_ratio=policy.straggler_ratio)
    backoffs = 0

    queue = list(tasks)
    while queue:
        wave = queue[:pack]
        queue = queue[pack:]
        k = len(wave)
        t_wave = time.perf_counter()
        if gauges is not None:
            gauges.on_dispatch(tenant, nodes=1, lanes=k,
                               resident_bytes=bytes_per_lane * k)
        keys = jnp.stack([jax.random.PRNGKey(t.seed) for t in wave])
        params = packing.pack_init(model.init, keys)
        opt_state = jax.vmap(opt.init)(params)
        lrs = jnp.asarray([t.lr for t in wave], jnp.float32)
        ckpt = (Checkpointer(f"{checkpoint_dir}/wave_{wave[0].id}")
                if checkpoint_dir else None)
        start = 0
        if ckpt is not None:
            try:
                (params, opt_state), start, _ = ckpt.restore((params, opt_state))
            except FileNotFoundError:
                pass
        for step in range(start, steps):
            batch = packing.stack_trees([
                jax.tree_util.tree_map(jnp.asarray, batch_fn(t.seed, step))
                for t in wave])
            mon.start_step()
            try:
                params, opt_state, metrics = packed_fn(
                    params, opt_state, batch, lrs)
            except Exception as e:  # OOM backoff: halve, re-enqueue halves
                if policy.oom_backoff and k > policy.min_pack_factor:
                    backoffs += 1
                    pack = max(policy.min_pack_factor, pack // 2)
                    queue = list(wave) + queue
                    params = opt_state = None
                    break
                raise
            mon.end_step(step)
            loss_vec = np.asarray(metrics["loss"])
            for i, t in enumerate(wave):
                losses[t.id].append(float(loss_vec[i]))
            if ckpt is not None and policy.checkpoint_every and \
                    (step + 1) % policy.checkpoint_every == 0:
                ckpt.save((params, opt_state), step + 1, blocking=False)
        if ckpt is not None and params is not None:
            ckpt.save((params, opt_state), steps)
            ckpt.wait()
        if gauges is not None:
            gauges.on_release(tenant, nodes=1,
                              node_time=time.perf_counter() - t_wave,
                              lanes=k, resident_bytes=bytes_per_lane * k)

    return SweepResult(losses=losses, wall_s=time.perf_counter() - t0,
                       pack_factor=pack, backoffs=backoffs,
                       bytes_per_lane=bytes_per_lane,
                       admission_capped=admission_capped)

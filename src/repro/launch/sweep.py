"""Parametric-study sweep driver — the paper's headline use case.

Runs K training tasks (same architecture, different hyperparameters / data
seeds) under a triples placement: auto_nppn picks the largest safe packing
factor, tasks pack as vmapped lanes, the monitor watches for stragglers,
checkpoints make OOM-backoff / node-loss recovery lossless.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.checkpoint import Checkpointer
from repro.core import autotune, packing, triples as T
from repro.core.faults import FaultPolicy, TaskOOM
from repro.core.monitor import RunMonitor
from repro.launch.train import make_train_step
from repro.models.model import Model


@dataclasses.dataclass
class SweepTask:
    id: int
    lr: float
    seed: int


@dataclasses.dataclass
class SweepResult:
    losses: Dict[int, List[float]]
    wall_s: float
    pack_factor: int
    backoffs: int = 0


def run_sweep(model: Model, tasks: Sequence[SweepTask], *,
              batch_fn: Callable[[int, int], Any],   # (seed, step) -> batch
              steps: int,
              hbm_budget: Optional[float] = None,
              max_pack: Optional[int] = None,
              checkpoint_dir: Optional[str] = None,
              policy: Optional[FaultPolicy] = None,
              opt: Optional[optim.Optimizer] = None) -> SweepResult:
    """Train all tasks; packing factor chosen by the memory guard."""
    policy = policy or FaultPolicy()
    opt = opt or optim.adamw(weight_decay=0.0)
    step_fn = make_train_step(model, opt)

    # ---- choose packing factor (auto_nppn) ----
    n = len(tasks)
    if max_pack is None:
        max_pack = n
    if hbm_budget is not None:
        def make_packed(k):
            return jax.vmap(step_fn)

        def example_args(k):
            keys = jax.random.split(jax.random.PRNGKey(0), k)
            p = jax.vmap(model.init)(keys)
            o = jax.vmap(opt.init)(p)
            b = jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x, (k, *x.shape)),
                jax.tree_util.tree_map(jnp.asarray, batch_fn(0, 0)))
            lr = jnp.zeros((k,), jnp.float32)
            return (p, o, b, lr)

        decision = autotune.auto_nppn(make_packed, example_args,
                                      hbm_budget, max_factor=max_pack)
        pack = decision.nppn_per_chip
    else:
        pack = min(max_pack, n)

    # ---- run waves of `pack` lanes ----
    t0 = time.perf_counter()
    losses: Dict[int, List[float]] = {t.id: [] for t in tasks}
    packed_fn = packing.packed_step(step_fn)
    mon = RunMonitor(straggler_ratio=policy.straggler_ratio)
    backoffs = 0

    queue = list(tasks)
    while queue:
        wave = queue[:pack]
        queue = queue[pack:]
        k = len(wave)
        keys = jnp.stack([jax.random.PRNGKey(t.seed) for t in wave])
        params = packing.pack_init(model.init, keys)
        opt_state = jax.vmap(opt.init)(params)
        lrs = jnp.asarray([t.lr for t in wave], jnp.float32)
        ckpt = (Checkpointer(f"{checkpoint_dir}/wave_{wave[0].id}")
                if checkpoint_dir else None)
        start = 0
        if ckpt is not None:
            try:
                (params, opt_state), start, _ = ckpt.restore((params, opt_state))
            except FileNotFoundError:
                pass
        for step in range(start, steps):
            batch = packing.stack_trees([
                jax.tree_util.tree_map(jnp.asarray, batch_fn(t.seed, step))
                for t in wave])
            mon.start_step()
            try:
                params, opt_state, metrics = packed_fn(
                    params, opt_state, batch, lrs)
            except Exception as e:  # OOM backoff: halve, re-enqueue halves
                if policy.oom_backoff and k > policy.min_pack_factor:
                    backoffs += 1
                    pack = max(policy.min_pack_factor, pack // 2)
                    queue = list(wave) + queue
                    params = opt_state = None
                    break
                raise
            mon.end_step(step)
            loss_vec = np.asarray(metrics["loss"])
            for i, t in enumerate(wave):
                losses[t.id].append(float(loss_vec[i]))
            if ckpt is not None and policy.checkpoint_every and \
                    (step + 1) % policy.checkpoint_every == 0:
                ckpt.save((params, opt_state), step + 1, blocking=False)
        if ckpt is not None and params is not None:
            ckpt.save((params, opt_state), steps)
            ckpt.wait()

    return SweepResult(losses=losses, wall_s=time.perf_counter() - t0,
                       pack_factor=pack, backoffs=backoffs)

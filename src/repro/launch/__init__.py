from repro.launch.mesh import make_production_mesh, make_mesh, mesh_name  # noqa: F401

"""Production meshes. Functions, not module constants — importing this must
never touch jax device state (the dry-run sets device-count flags first)."""
from __future__ import annotations

import jax

import repro.compat  # noqa: F401  (backfills AxisType / axis_types on old jax)
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single pod (256 chips) or 2×16×16 two pods (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(shape))


def make_mesh(shape, axes):
    """Arbitrary mesh with Auto axis types (GSPMD propagation)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(AxisType.Auto,) * len(shape))


def mesh_name(mesh) -> str:
    return "x".join(f"{mesh.shape[a]}{a}" for a in mesh.axis_names)

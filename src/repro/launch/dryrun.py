import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=" +
                           os.environ.get("DRYRUN_DEVICES", "512")).strip()

"""Multi-pod dry-run: lower + compile every (architecture × shape × mesh)
cell with ShapeDtypeStruct stand-ins (no allocation), print
memory_analysis/cost_analysis, and emit roofline rows to JSON artifacts.

MUST set XLA_FLAGS before any jax import (above) — jax locks the device
count at first init. Run as:

    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh both --out artifacts/dryrun
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro import configs, optim
from repro.configs.base import SHAPES_BY_NAME, ShapeSpec, cell_is_runnable
from repro.distributed.sharding import ShardingRules, batch_shardings
from repro.launch.mesh import make_production_mesh, mesh_name
from repro.launch.serve import make_prefill, make_serve_step
from repro.launch.train import make_train_step
from repro.models.model import Model
from repro.models.transformer import ParallelCtx
from repro.roofline.analysis import analyze_compiled

# zamba2's shared attention runs a 4096 sliding window at 500k (DESIGN.md)
LONG_WINDOW = {"zamba2-7b": 4096}


def build_model(arch: str, shape: ShapeSpec, mesh,
                overrides: Optional[dict] = None,
                opt: Optional[dict] = None) -> Model:
    """opt: perf-iteration flags (§Perf) —
    pad_heads: TP head padding; score_bf16: bf16 softmax-prob traffic;
    ep_bf16: bf16 EP combine psum."""
    opt = opt or {}
    cfg = configs.get(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    if opt.get("pad_heads"):
        cfg = cfg.tp_pad_heads(mesh.shape["model"])
    window = None
    if shape.name == "long_500k":
        window = LONG_WINDOW.get(arch)
    pctx = ParallelCtx(mesh=mesh, ep=(cfg.family == "moe"),
                       score_bf16=bool(opt.get("score_bf16")),
                       ep_bf16=bool(opt.get("ep_bf16")))
    return Model(cfg, pctx=pctx, window=window)


def lower_cell(arch: str, shape_name: str, mesh, *,
               fsdp: bool = True, donate: bool = True,
               overrides: Optional[dict] = None,
               opt: Optional[dict] = None):
    """Returns (lowered, n_tokens, kind, model)."""
    shape = SHAPES_BY_NAME[shape_name]
    model = build_model(arch, shape, mesh, overrides, opt)
    cfg = model.cfg
    rules = ShardingRules(mesh, fsdp=fsdp)

    key = jax.random.PRNGKey(0)
    p_spec = jax.eval_shape(model.init, key)
    p_shard = rules.shardings(p_spec)
    batch_spec = model.input_specs(shape)

    if shape.kind == "train":
        opt = optim.adamw()
        o_spec = jax.eval_shape(opt.init, p_spec)
        o_shard = jax.tree_util.tree_map(
            lambda leaf_spec: None, o_spec)
        # opt moments share the param sharding; count replicated
        o_shard = {
            "mu": rules.shardings(o_spec["mu"]),
            "nu": rules.shardings(o_spec["nu"]),
            "count": jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec()),
        }
        b_shard = batch_shardings(mesh, batch_spec, shape.global_batch)
        lr_shard = jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec())
        step = make_train_step(model, opt)
        jitted = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard,
                                             lr_shard),
                         donate_argnums=(0, 1) if donate else ())
        lowered = jitted.lower(p_spec, o_spec, batch_spec,
                               jax.ShapeDtypeStruct((), jnp.float32))
        n_tokens = shape.tokens
        kind = "train"
    elif shape.kind == "prefill":
        b_shard = batch_shardings(mesh, batch_spec, shape.global_batch)
        fn = make_prefill(model, max_len=shape.seq_len)
        jitted = jax.jit(fn, in_shardings=(p_shard, b_shard))
        lowered = jitted.lower(p_spec, batch_spec)
        n_tokens = shape.tokens
        kind = "inference"
    else:  # decode
        cache_spec = batch_spec.pop("_cache")
        b_shard = batch_shardings(mesh, batch_spec, shape.global_batch)
        c_shard = batch_shardings(mesh, cache_spec, shape.global_batch)
        fn = make_serve_step(model)
        jitted = jax.jit(fn, in_shardings=(p_shard, b_shard, c_shard),
                         donate_argnums=(2,) if donate else ())
        lowered = jitted.lower(p_spec, batch_spec, cache_spec)
        n_tokens = shape.global_batch  # one new token per sequence
        kind = "inference"
    return lowered, n_tokens, kind, model


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             fsdp: bool = True, overrides: Optional[dict] = None,
             opt: Optional[dict] = None,
             tag: str = "") -> Optional[dict]:
    mesh = make_production_mesh(multi_pod=multi_pod)
    mname = mesh_name(mesh)
    label = f"{arch} × {shape_name} × {mname}" + (f" [{tag}]" if tag else "")
    if not cell_is_runnable(arch, shape_name):
        print(f"[dryrun] SKIP {label} (documented: this cell needs "
              f"sub-quadratic attention or a decoder arch)")
        return None
    t0 = time.perf_counter()
    try:
        with mesh:
            lowered, n_tokens, kind, model = lower_cell(
                arch, shape_name, mesh, fsdp=fsdp, overrides=overrides,
                opt=opt)
            compiled = lowered.compile()
        ma = compiled.memory_analysis()
        # MODEL_FLOPS uses the ASSIGNED (unpadded) architecture
        base_cfg = configs.get(arch)
        if overrides:
            base_cfg = dataclasses.replace(base_cfg, **overrides)
        n_params = base_cfg.active_param_count()   # 6·N_active·D for MoE
        rep = analyze_compiled(
            compiled, arch=arch, shape=shape_name, mesh_name=mname,
            chips=mesh.size, n_params=n_params, n_tokens=n_tokens, kind=kind)
        from repro.roofline.analysis import attn_kernel_io_bytes
        rep.kernel_io_bytes = attn_kernel_io_bytes(
            model.cfg, SHAPES_BY_NAME[shape_name].tokens
            if kind != "inference" or shape_name.startswith("prefill")
            else n_tokens, mesh, kind)
        row = rep.row()
        row.update({
            "bytes_by_tag_gb": {k: v / 1e9 for k, v in rep.bytes_by_tag.items()},
            "kernel_io_gb_dev": rep.kernel_io_bytes / 1e9,
            "t_memory_kernel_s": rep.t_memory_kernel,
            "roofline_fraction_kernel": rep.roofline_fraction_kernel,
        })
        row.update({
            "compile_s": time.perf_counter() - t0,
            "arg_gb_dev": ma.argument_size_in_bytes / 1e9,
            "temp_gb_dev": ma.temp_size_in_bytes / 1e9,
            "alias_gb_dev": ma.alias_size_in_bytes / 1e9,
            "coll_by_kind_gb": {k: v / 1e9 for k, v in rep.coll_by_kind.items()},
            "coll_traffic_gb_dev": rep.coll_traffic_bytes / 1e9,
            "tag": tag or "baseline",
        })
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"__{tag}" if tag else ""
        fname = f"{arch}__{shape_name}__{mname}{suffix}.json"
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(row, f, indent=1)
        print(f"[dryrun] OK   {label}: "
              f"mem/dev arg={row['arg_gb_dev']:.2f}+tmp={row['temp_gb_dev']:.2f}GB "
              f"flops/dev={row['hlo_gflops_dev']:.1f}G "
              f"coll/dev={row['coll_gb_dev']:.3f}GB "
              f"bottleneck={row['bottleneck']} "
              f"roofline={row['roofline_fraction']:.3f} "
              f"({row['compile_s']:.0f}s)")
        return row
    except Exception as e:  # noqa: BLE001 — a failed cell is a bug; report it
        print(f"[dryrun] FAIL {label}: {type(e).__name__}: {e}")
        traceback.print_exc()
        return {"arch": arch, "shape": shape_name, "mesh": mname,
                "error": f"{type(e).__name__}: {e}", "tag": tag or "baseline"}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--no-fsdp", action="store_true")
    args = ap.parse_args()

    archs = list(configs.available()) if args.arch == "all" else [args.arch]
    shapes = (list(SHAPES_BY_NAME) if args.shape == "all"
              else [args.shape])
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    n_ok = n_fail = n_skip = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                row = run_cell(arch, shape, mp, args.out,
                               fsdp=not args.no_fsdp)
                jax.clear_caches()   # bound host RAM across 64 compiles
                if row is None:
                    n_skip += 1
                elif "error" in row:
                    n_fail += 1
                else:
                    n_ok += 1
    print(f"[dryrun] done: {n_ok} ok, {n_fail} failed, {n_skip} skipped")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

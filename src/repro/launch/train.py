"""Training driver: step builder (used by dry-run, tests, examples) plus a
fault-tolerant training loop with checkpointing and monitoring."""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.checkpoint import Checkpointer
from repro.core.monitor import RunMonitor
from repro.models.model import Model


def make_train_step(model: Model, opt: optim.Optimizer) -> Callable:
    """(params, opt_state, batch, lr) -> (params, opt_state, metrics)."""

    def train_step(params, opt_state, batch, lr):
        (_, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(params, batch)
        updates, opt_state = opt.update(grads, opt_state, params, lr)
        params = optim.apply_updates(params, updates)
        metrics["grad_norm"] = optim.global_norm(grads)
        return params, opt_state, metrics

    return train_step


def make_eval_step(model: Model) -> Callable:
    def eval_step(params, batch):
        _, metrics = model.loss(params, batch)
        return metrics
    return eval_step


@dataclasses.dataclass
class Trainer:
    """Single-task training loop (lanes of a packed sweep reuse the same
    step through core.packing instead)."""
    model: Model
    opt: optim.Optimizer
    lr_schedule: Callable
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 100
    log_every: int = 10

    def fit(self, key, batch_iter, steps: int,
            params: Any = None, opt_state: Any = None,
            start_step: int = 0) -> Dict[str, Any]:
        model, opt = self.model, self.opt
        if params is None:
            params = model.init(key)
        if opt_state is None:
            opt_state = opt.init(params)
        ckpt = (Checkpointer(self.checkpoint_dir)
                if self.checkpoint_dir else None)
        if ckpt is not None:
            try:
                (params, opt_state), start_step, _ = ckpt.restore(
                    (params, opt_state))
                print(f"[trainer] resumed from step {start_step}")
            except FileNotFoundError:
                pass

        step_fn = jax.jit(make_train_step(model, opt),
                          donate_argnums=(0, 1))
        mon = RunMonitor()
        losses = []
        it = iter(batch_iter)
        for step in range(start_step, steps):
            batch = next(it)
            batch = jax.tree_util.tree_map(jnp.asarray, batch)
            mon.start_step()
            params, opt_state, metrics = step_fn(
                params, opt_state, batch, self.lr_schedule(step))
            loss = float(metrics["loss"])
            mon.end_step(step)
            losses.append(loss)
            if self.log_every and step % self.log_every == 0:
                print(f"[trainer] step {step} loss {loss:.4f} "
                      f"({mon.history[-1].wall_s*1e3:.0f} ms)")
            if ckpt is not None and (step + 1) % self.checkpoint_every == 0:
                ckpt.save((params, opt_state), step + 1, blocking=False)
        if ckpt is not None:
            ckpt.save((params, opt_state), steps)
            ckpt.wait()
        return {"params": params, "opt_state": opt_state,
                "losses": losses, "monitor": mon.summary()}
